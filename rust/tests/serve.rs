//! `alb serve` integration: protocol abuse over real TCP sockets, cache
//! byte-identity, deterministic coalescing, the batch-vs-serve parity
//! matrix (a served `labels_hash` must be bit-identical to `alb run` for
//! the same query), and the multi-client soak (EXPERIMENTS.md, DESIGN.md
//! §16). Everything runs against an ephemeral-port daemon per test, so
//! tests parallelize freely.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::Command;

use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::inputs;
use alb_graph::serve::{ServeOpts, Server, ServerHandle};
use alb_graph::session::Session;

const DELTA: i32 = -4; // small but non-trivial inputs for CI
const SEED: u64 = 42;

/// The exact session `alb serve --graph <input> --scale-delta -4` builds:
/// default framework + spec, pinned worker count so parity against the CLI
/// is apples-to-apples.
fn session(input: &'static str) -> Session {
    let g = inputs::build(input, DELTA, SEED).unwrap();
    let fw = Framework::parse("dirgl-alb").unwrap();
    let spec = GpuSpec::by_name("sim-default").unwrap();
    Session::new(g, input, fw.engine_config(spec).with_sim_threads(2))
}

fn spawn(input: &'static str, opts: ServeOpts) -> ServerHandle {
    Server::spawn(session(input), opts, 0).unwrap()
}

/// One line-delimited-JSON client connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(h: &ServerHandle) -> Client {
        let s = TcpStream::connect(h.addr()).unwrap();
        Client { reader: BufReader::new(s.try_clone().unwrap()), writer: s }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
    }

    /// Read one reply line; empty string on a closed connection.
    fn recv(&mut self) -> String {
        let mut s = String::new();
        self.reader.read_line(&mut s).unwrap();
        s.trim_end().to_string()
    }

    fn round_trip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

/// Extract a scalar field from a compact reply. Only valid for
/// non-object values (fine for everything but `result`).
fn field(json: &str, key: &str) -> String {
    let pat = format!("\"{key}\":");
    let start = json
        .find(&pat)
        .unwrap_or_else(|| panic!("{key} missing in {json}"))
        + pat.len();
    let rest = &json[start..];
    let end = rest
        .find([',', '}'])
        .unwrap_or_else(|| panic!("unterminated {key} in {json}"));
    rest[..end].trim_matches('"').to_string()
}

fn field_u64(json: &str, key: &str) -> u64 {
    field(json, key).parse().unwrap()
}

// --------------------------------------------------------- protocol abuse

#[test]
fn protocol_errors_are_structured_and_the_session_survives() {
    let h = spawn("road-s", ServeOpts::default());
    let mut c = Client::connect(&h);
    for (bad, needle) in [
        ("{not json", "error"),
        (r#"{"app":"zzz"}"#, "valid values"),
        (r#"{"op":"bogus","app":"bfs"}"#, "valid values"),
        (r#"{"app":"bfs","frobnicate":1}"#, "valid fields"),
        (r#"{"app":"bfs","source":4000000000}"#, "out of range"),
        (r#"{"app":"bfs","vertex":4000000000}"#, "out of range"),
        (r#"{"app":"bfs","k":0}"#, "valid values"),
        (r#"{"app":"bfs","max_rounds":4000000000}"#, "budget"),
        (r#"[1,2,3]"#, "object"),
    ] {
        let reply = c.round_trip(bad);
        assert_eq!(field(&reply, "status"), "error", "{bad} -> {reply}");
        assert!(reply.contains(needle), "{bad} -> {reply}");
        assert!(reply.contains("\"schema_version\""), "{reply}");
    }
    // The same connection — and the shared session behind it — still
    // answers correctly after every abuse above.
    let ok = c.round_trip(r#"{"app":"bfs","source":0}"#);
    assert_eq!(field(&ok, "status"), "ok", "{ok}");
    assert_eq!(field(&ok, "cache"), "miss", "{ok}");
    let stats = c.round_trip(r#"{"op":"stats"}"#);
    assert_eq!(field_u64(&stats, "errors"), 9, "{stats}");
    assert_eq!(field_u64(&stats, "executed"), 1, "{stats}");
    h.stop();
}

#[test]
fn oversized_line_gets_an_error_then_close() {
    let h = spawn("road-s", ServeOpts::default());
    let mut c = Client::connect(&h);
    let huge = format!("{}{}", r#"{"app":"bfs","id":""#, "x".repeat(70 * 1024));
    c.send(&huge);
    let reply = c.recv();
    assert_eq!(field(&reply, "status"), "error", "{reply}");
    assert!(reply.contains("bytes"), "{reply}");
    // The stream cannot be resynchronized: the server closes it.
    assert_eq!(c.recv(), "", "connection should be closed after oversize");
    // A fresh connection is unaffected.
    let mut c2 = Client::connect(&h);
    let ok = c2.round_trip(r#"{"app":"bfs","source":0}"#);
    assert_eq!(field(&ok, "status"), "ok", "{ok}");
    h.stop();
}

#[test]
fn mid_request_disconnect_is_a_clean_drop() {
    let h = spawn("road-s", ServeOpts::default());
    {
        let mut s = TcpStream::connect(h.addr()).unwrap();
        // A partial request with no newline, then a dead client.
        s.write_all(b"{\"app\":\"bfs\",\"sour").unwrap();
        s.flush().unwrap();
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
    // The partial line is dropped, never half-parsed: no error is counted
    // and the shared session still serves the next client.
    let mut c = Client::connect(&h);
    let ok = c.round_trip(r#"{"app":"bfs","source":0}"#);
    assert_eq!(field(&ok, "status"), "ok", "{ok}");
    let stats = c.round_trip(r#"{"op":"stats"}"#);
    assert_eq!(field_u64(&stats, "errors"), 0, "{stats}");
    h.stop();
}

// ------------------------------------------------------- cache byte-identity

#[test]
fn cache_hit_is_byte_identical_over_tcp() {
    let h = spawn("road-s", ServeOpts::default());
    let mut c = Client::connect(&h);
    let line = r#"{"app":"sssp","source":0}"#;
    let cold = c.round_trip(line);
    let hit = c.round_trip(line);
    assert_eq!(field(&cold, "cache"), "miss", "{cold}");
    assert_eq!(field(&hit, "cache"), "hit", "{hit}");
    assert_eq!(
        cold.replace("\"cache\":\"miss\"", "\"cache\":\"hit\""),
        hit,
        "a cache hit must be byte-identical apart from the cache field"
    );
    // Equivalent spellings share one cache line: an explicit default is
    // the same identity as an omitted field.
    let respelled = c.round_trip(r#"{"app":"sssp","source":0,"op":"query"}"#);
    assert_eq!(field(&respelled, "cache"), "hit", "{respelled}");
    h.stop();
}

// ------------------------------------------------------------- parity gate

/// The acceptance gate: a served query's `labels_hash` is bit-identical to
/// `alb run` on the same graph/app/source, across every app. Both sides run
/// the identical Session path; this pins the whole transport stack
/// (protocol parse -> effective config -> execution -> render) to the
/// batch CLI.
#[test]
fn serve_matches_alb_run_bit_for_bit() {
    let h = spawn("road-s", ServeOpts::default());
    let mut c = Client::connect(&h);
    for app in ["bfs", "sssp", "cc", "pr", "kcore"] {
        let path = std::env::temp_dir()
            .join(format!("alb-serve-parity-{}-{app}.json", std::process::id()));
        let out = Command::new(env!("CARGO_BIN_EXE_alb"))
            .args([
                "run", "--app", app, "--input", "road-s", "--scale-delta", "-4",
                "--sim-threads", "2", "--json", path.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let run_json = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let want_hash = run_json
            .lines()
            .find_map(|l| l.trim().strip_prefix("\"labels_hash\": \""))
            .and_then(|rest| rest.split('"').next())
            .unwrap_or_else(|| panic!("no labels_hash in {run_json}"))
            .to_string();
        let want_source: u32 = run_json
            .lines()
            .find_map(|l| l.trim().strip_prefix("\"source\": "))
            .map(|rest| rest.trim_end_matches(',').parse().unwrap())
            .unwrap();

        // An omitted source resolves to the same paper policy `alb run`
        // uses, so the minimal query is already the parity twin.
        let reply = c.round_trip(&format!(r#"{{"app":"{app}"}}"#));
        assert_eq!(field(&reply, "status"), "ok", "{reply}");
        assert_eq!(
            field(&reply, "labels_hash"),
            want_hash,
            "{app}: serve hash != alb run hash ({reply})"
        );
        assert_eq!(field_u64(&reply, "source"), u64::from(want_source), "{reply}");
    }
    h.stop();
}

/// Source matrix: for arbitrary explicit sources the daemon must agree
/// with a direct in-process `Session::run` (the same API `alb run` sits
/// on), query after query on one long-lived server.
#[test]
fn serve_matches_session_across_sources() {
    use alb_graph::apps::App;
    use alb_graph::session::RunRequest;

    let reference = session("rmat18");
    let h = spawn("rmat18", ServeOpts::default());
    let mut c = Client::connect(&h);
    for app in [App::Bfs, App::Sssp] {
        for src in [0u32, 5, 17, 1023] {
            let req = RunRequest::new(app).with_source(src);
            let want = reference.run(&req, None).unwrap();
            let reply = c.round_trip(&format!(
                r#"{{"app":"{}","source":{src}}}"#,
                app.name()
            ));
            assert_eq!(field(&reply, "status"), "ok", "{reply}");
            assert_eq!(
                field(&reply, "labels_hash"),
                want.labels_hash,
                "{} source {src}: serve != session ({reply})",
                app.name()
            );
        }
    }
    h.stop();
}

// ------------------------------------------------------------- coalescing

/// Deterministic coalesce: with one admission slot and the cache disabled,
/// a long query holds the slot, a second key's leader blocks at admission
/// (its flight is registered *before* admission, which is the property
/// under test), and a third same-key arrival joins that flight instead of
/// executing.
#[test]
fn same_key_arrivals_coalesce_onto_a_blocked_leader() {
    let h = spawn(
        "rmat18",
        ServeOpts { max_inflight: 1, cache_entries: 0, ..ServeOpts::default() },
    );
    let mut stats = Client::connect(&h);

    // Qa: a full PageRank solve — long enough to hold the only slot for
    // the whole (microsecond-scale) choreography below.
    let mut ca = Client::connect(&h);
    ca.send(r#"{"app":"pr","id":"qa"}"#);
    while field_u64(&stats.round_trip(r#"{"op":"stats"}"#), "pending") < 1 {
        std::thread::yield_now();
    }

    // Qb's leader: registers its flight, then blocks at admission.
    let mut cb = Client::connect(&h);
    cb.send(r#"{"app":"bfs","source":3,"id":"qb-leader"}"#);
    while field_u64(&stats.round_trip(r#"{"op":"stats"}"#), "pending") < 2 {
        std::thread::yield_now();
    }

    // Qb again: must join the blocked leader's flight — with the cache
    // off, `coalesced` is the only way this reply avoids a third run.
    let mut cc = Client::connect(&h);
    let joined = cc.round_trip(r#"{"app":"bfs","source":3,"id":"qb-join"}"#);
    assert_eq!(field(&joined, "cache"), "coalesced", "{joined}");
    assert_eq!(field(&joined, "id"), "qb-join", "{joined}");

    let lead = cb.recv();
    assert_eq!(field(&lead, "cache"), "miss", "{lead}");
    assert_eq!(
        field(&lead, "labels_hash"),
        field(&joined, "labels_hash"),
        "coalesced reply must carry the leader's result"
    );
    let qa = ca.recv();
    assert_eq!(field(&qa, "cache"), "miss", "{qa}");

    let final_stats = stats.round_trip(r#"{"op":"stats"}"#);
    assert_eq!(field_u64(&final_stats, "queries"), 3, "{final_stats}");
    assert_eq!(field_u64(&final_stats, "executed"), 2, "{final_stats}");
    assert_eq!(field_u64(&final_stats, "coalesced"), 1, "{final_stats}");
    assert_eq!(field_u64(&final_stats, "cache_hits"), 0, "{final_stats}");
    assert_eq!(field_u64(&final_stats, "pending"), 0, "{final_stats}");
    h.stop();
}

// -------------------------------------------------------------------- soak

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The soak: 8 concurrent clients x 20 rounds each, a deterministic
/// seeded schedule mixing all five apps over four sources. Asserts, from
/// reply metadata and the stats counters alone:
///
/// * every reply is `ok` with a well-formed `cache` status and a
///   round-tripped `id`;
/// * `labels_hash` is consistent per (app, resolved source) across all
///   160 replies — concurrency never changes an answer;
/// * `executed` == the number of distinct query identities (the
///   cache-before-flight-retire ordering makes this an equality, not a
///   bound);
/// * `executed + cache_hits + coalesced == queries` with zero errors;
/// * the cache demonstrably served repeats (`cache_hits >= 1` — each
///   sequential client repeats a key it already completed, which by then
///   must be cached).
#[test]
fn soak_eight_clients_mixed_apps_and_sources() {
    const CLIENTS: u64 = 8;
    const ROUNDS: usize = 20;
    const APPS: [&str; 5] = ["bfs", "sssp", "cc", "pr", "kcore"];
    const SOURCES: [u32; 4] = [0, 3, 11, 29];

    let h = spawn("rmat18", ServeOpts::default());
    let addr = h.addr();
    let mut workers = Vec::new();
    for client in 0..CLIENTS {
        workers.push(std::thread::spawn(move || {
            let s = TcpStream::connect(addr).unwrap();
            let mut c =
                Client { reader: BufReader::new(s.try_clone().unwrap()), writer: s };
            let mut rng = SEED ^ (client.wrapping_mul(0x9E37_79B9));
            let mut hashes: BTreeMap<String, String> = BTreeMap::new();
            for round in 0..ROUNDS {
                let r = splitmix64(&mut rng);
                let app = APPS[(r % 5) as usize];
                let src = SOURCES[((r >> 8) % 4) as usize];
                let id = format!("c{client}-r{round}");
                let reply = c.round_trip(&format!(
                    r#"{{"app":"{app}","source":{src},"id":"{id}"}}"#
                ));
                assert_eq!(field(&reply, "status"), "ok", "{reply}");
                assert_eq!(field(&reply, "id"), id, "{reply}");
                let cache = field(&reply, "cache");
                assert!(
                    ["miss", "hit", "coalesced"].contains(&cache.as_str()),
                    "{reply}"
                );
                // Key by the *resolved* source: sourceless apps
                // canonicalize, so their four spellings must land on one
                // identity (and therefore one hash).
                let key = format!("{app}|{}", field(&reply, "source"));
                hashes.insert(key, field(&reply, "labels_hash"));
            }
            hashes
        }));
    }

    let mut merged: BTreeMap<String, String> = BTreeMap::new();
    for w in workers {
        for (key, hash) in w.join().unwrap() {
            if let Some(prev) = merged.get(&key) {
                assert_eq!(prev, &hash, "{key}: hash diverged across clients");
            }
            merged.insert(key, hash);
        }
    }

    let mut c = Client::connect(&h);
    let stats = c.round_trip(r#"{"op":"stats"}"#);
    let queries = field_u64(&stats, "queries");
    let executed = field_u64(&stats, "executed");
    let cache_hits = field_u64(&stats, "cache_hits");
    let coalesced = field_u64(&stats, "coalesced");
    assert_eq!(queries, CLIENTS * ROUNDS as u64, "{stats}");
    assert_eq!(field_u64(&stats, "errors"), 0, "{stats}");
    assert_eq!(field_u64(&stats, "pending"), 0, "{stats}");
    assert_eq!(
        executed,
        merged.len() as u64,
        "each distinct identity executes exactly once ({stats})"
    );
    assert_eq!(
        executed + cache_hits + coalesced,
        queries,
        "counter invariant broken ({stats})"
    );
    assert!(cache_hits >= 1, "repeats never hit the cache ({stats})");
    h.stop();
}
