//! Zero-allocation gate for the steady-state engine round loop.
//!
//! A thread-local counting allocator wraps the system allocator; the test
//! warms a [`RoundScratch`] arena with a few rounds, then drives the exact
//! engine round body — `Balancer::schedule_into` →
//! `Simulator::simulate_into` → native relaxation → bitmap frontier drain —
//! repeatedly and asserts the measuring thread performs **zero** heap
//! allocations once capacities have warmed (ISSUE 2 acceptance; DESIGN.md
//! §8). Counting is per-thread, so the harness running other test threads
//! concurrently cannot pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use alb_graph::apps::engine::RoundScratch;
use alb_graph::comm::exchange::{ExchangePlan, Flow, HasPartState, PartState};
use alb_graph::comm::{superstep_mut, ExecMode};
use alb_graph::exec::Pool;
use alb_graph::gpu::{CostModel, GpuSpec, Simulator};
use alb_graph::graph::reorder::{self, Reorder};
use alb_graph::graph::{CsrGraph, EdgeList};
use alb_graph::lb::{Balancer, Direction, Distribution};
use alb_graph::partition::{partition, Policy};

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

// SAFETY: a pure pass-through wrapper — every method forwards its exact
// arguments to the std `System` allocator and upholds `GlobalAlloc`'s
// contract by inheritance; the only added work is a thread-local counter
// bump, which cannot allocate (`Cell<u64>`) or unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller obligations (valid layout) are forwarded unchanged
    // to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    // SAFETY: caller obligations are forwarded unchanged to
    // `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller obligations (ptr from this allocator, matching
    // layout) are forwarded unchanged to `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller obligations (ptr from this allocator, matching
    // layout) are forwarded unchanged to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// A graph whose hub crosses the default huge threshold (3072 launched
/// threads), so the round exercises the full ALB path: inspector split, LB
/// launch buffers, cache-modeled LB simulation, and TWC binning.
fn hub_graph() -> CsrGraph {
    let n = 20_000u32;
    let mut el = EdgeList::new(n);
    for i in 0..8_000u32 {
        el.push(0, 1 + (i % (n - 1)), 1.0);
    }
    for v in 1..4_000u32 {
        el.push(v, (v * 7) % n, 1.0);
    }
    CsrGraph::from_edge_list(&el)
}

#[test]
fn steady_state_engine_round_loop_is_allocation_free() {
    let g = hub_graph();
    let n = g.num_vertices();
    let spec = GpuSpec::default_sim();
    let sim = Simulator::new(spec.clone(), CostModel::default());
    let active: Vec<u32> = (0..4_000).collect();

    for balancer in [
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
        Balancer::Alb { distribution: Distribution::Blocked, threshold: None },
        Balancer::Twc,
        Balancer::EdgeLb { distribution: Distribution::Cyclic },
        Balancer::Vertex,
        Balancer::Enterprise,
        // The controller's starting composition — identical schedule to
        // plain ALB, and its schedule path must stay allocation-free too.
        Balancer::Adaptive { distribution: Distribution::Cyclic, threshold: None },
    ] {
        let mut scratch = RoundScratch::for_vertices(n);
        let mut labels = vec![f32::INFINITY; n];

        // One full engine round body, exactly as `run_push` executes it.
        let round = |labels: &mut Vec<f32>, scratch: &mut RoundScratch| {
            // Reset labels so every iteration relaxes the same edges and
            // produces the same frontier (fill: no allocation).
            labels.fill(f32::INFINITY);
            for &v in &active {
                labels[v as usize] = 0.0;
            }
            balancer.schedule_into(
                &active, &g, Direction::Push, &spec, n as u64,
                &mut scratch.sched,
            );
            sim.simulate_into(&scratch.sched.sched, true, &mut scratch.sim);
            for &v in &active {
                let dv = labels[v as usize];
                let (dsts, ws) = g.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    // sssp-style relaxation: candidate = source + weight.
                    let cand = dv + w;
                    if cand < labels[dst as usize] {
                        labels[dst as usize] = cand;
                        scratch.next.push(dst);
                    }
                }
            }
            scratch.next.take_sorted_into(&mut scratch.active);
            scratch.active.len()
        };

        // Warm the arena: first rounds grow every buffer to capacity.
        let warm = round(&mut labels, &mut scratch);
        assert!(warm > 0, "warmup must produce a frontier");
        for _ in 0..2 {
            round(&mut labels, &mut scratch);
        }

        // Steady state: zero allocations on this thread across many rounds.
        let before = allocs_on_this_thread();
        for _ in 0..10 {
            round(&mut labels, &mut scratch);
        }
        let after = allocs_on_this_thread();
        assert_eq!(
            after - before,
            0,
            "steady-state rounds allocated under {}",
            balancer.name()
        );
    }
}

#[test]
fn steady_state_round_loop_on_reordered_graph_is_allocation_free() {
    // ISSUE 7: reordering happens once at build time and hands the engine
    // an ordinary CsrGraph — the steady-state round loop must stay
    // allocation-free on it. Degree ordering renames the hub to vertex 0,
    // so the 0..4000 active set still drives the full ALB split.
    let g0 = hub_graph();
    for kind in [Reorder::Degree, Reorder::Rcm] {
        let (g, _perm) = reorder::reorder(&g0, kind);
        let n = g.num_vertices();
        let spec = GpuSpec::default_sim();
        let sim = Simulator::new(spec.clone(), CostModel::default());
        let active: Vec<u32> = (0..4_000).collect();
        let balancer =
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None };
        let mut scratch = RoundScratch::for_vertices(n);
        let mut labels = vec![f32::INFINITY; n];

        let round = |labels: &mut Vec<f32>, scratch: &mut RoundScratch| {
            labels.fill(f32::INFINITY);
            for &v in &active {
                labels[v as usize] = 0.0;
            }
            balancer.schedule_into(
                &active, &g, Direction::Push, &spec, n as u64,
                &mut scratch.sched,
            );
            sim.simulate_into(&scratch.sched.sched, true, &mut scratch.sim);
            for &v in &active {
                let dv = labels[v as usize];
                let (dsts, ws) = g.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    let cand = dv + w;
                    if cand < labels[dst as usize] {
                        labels[dst as usize] = cand;
                        scratch.next.push(dst);
                    }
                }
            }
            scratch.next.take_sorted_into(&mut scratch.active);
            scratch.active.len()
        };

        let warm = round(&mut labels, &mut scratch);
        assert!(warm > 0, "warmup must produce a frontier ({kind:?})");
        for _ in 0..2 {
            round(&mut labels, &mut scratch);
        }

        let before = allocs_on_this_thread();
        for _ in 0..10 {
            round(&mut labels, &mut scratch);
        }
        let after = allocs_on_this_thread();
        assert_eq!(
            after - before,
            0,
            "steady-state rounds on the {kind:?}-reordered graph allocated"
        );
    }
}

#[test]
fn steady_state_pooled_round_loop_is_allocation_free() {
    // DESIGN.md §9 + §8: with the worker pool enabled, the per-chunk
    // arenas (chunk cache models, line buffers, partial-result vectors)
    // and the stack-resident pool jobs keep the steady-state round loop
    // allocation-free on the submitting thread. Workers' warmup growth of
    // chunk buffers happens in the warm rounds; afterwards every chunk
    // slot is at capacity no matter which thread claims it. The active
    // set (4000) exceeds the pooled-split threshold, so the ALB inspector's
    // parallel probe pass is exercised too.
    let g = hub_graph();
    let n = g.num_vertices();
    let spec = GpuSpec::default_sim();
    let sim = Simulator::new(spec.clone(), CostModel::default());
    let active: Vec<u32> = (0..4_000).collect();
    let pool = Pool::new(4);

    for balancer in [
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
        Balancer::Alb { distribution: Distribution::Blocked, threshold: None },
        Balancer::Twc,
        Balancer::EdgeLb { distribution: Distribution::Cyclic },
        Balancer::Vertex,
        Balancer::Enterprise,
        // The controller's starting composition — identical schedule to
        // plain ALB, and its schedule path must stay allocation-free too.
        Balancer::Adaptive { distribution: Distribution::Cyclic, threshold: None },
    ] {
        let mut scratch = RoundScratch::for_vertices(n);
        let mut labels = vec![f32::INFINITY; n];

        // The engine round body, on the pooled entry points.
        let round = |labels: &mut Vec<f32>, scratch: &mut RoundScratch| {
            labels.fill(f32::INFINITY);
            for &v in &active {
                labels[v as usize] = 0.0;
            }
            balancer.schedule_into_pooled(
                &active, &g, Direction::Push, &spec, n as u64,
                &mut scratch.sched, &pool,
            );
            sim.simulate_into_pooled(&scratch.sched.sched, true, &mut scratch.sim, &pool);
            for &v in &active {
                let dv = labels[v as usize];
                let (dsts, ws) = g.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    let cand = dv + w;
                    if cand < labels[dst as usize] {
                        labels[dst as usize] = cand;
                        scratch.next.push(dst);
                    }
                }
            }
            scratch.next.take_sorted_into(&mut scratch.active);
            scratch.active.len()
        };

        let warm = round(&mut labels, &mut scratch);
        assert!(warm > 0, "warmup must produce a frontier");
        for _ in 0..2 {
            round(&mut labels, &mut scratch);
        }

        let before = allocs_on_this_thread();
        for _ in 0..10 {
            round(&mut labels, &mut scratch);
        }
        let after = allocs_on_this_thread();
        assert_eq!(
            after - before,
            0,
            "steady-state pooled rounds allocated under {}",
            balancer.name()
        );
    }
}

/// One simulated GPU's state for the distributed gate: the exchange side
/// (labels / frontier / changed buffer / bitmasks) plus the compute arena —
/// the same split the coordinator's `GpuPush` uses.
struct DistGpu {
    st: PartState,
    scratch: RoundScratch,
}

impl HasPartState for DistGpu {
    fn part_state(&mut self) -> &mut PartState {
        &mut self.st
    }
}

#[test]
fn steady_state_distributed_superstep_is_allocation_free() {
    // ISSUE 4 acceptance: a warmed BSP superstep — per-GPU compute tasks
    // dispatched in place through `superstep_mut`, then the plan-driven
    // reduce / broadcast over the precomputed mirror schedules — performs
    // zero heap allocations on the submitting thread. The per-GPU payloads
    // live in persistent `PartState` buffers (no per-round `changed` Vec),
    // the frontier is rebuilt into a capacity-reusing buffer, and the flow
    // list is cleared, not reallocated.
    let g = hub_graph();
    let dg = partition(&g, 4, Policy::Cvc);
    let plan = ExchangePlan::new(&dg);
    assert!(plan.total_mirrors() > 0, "partitioning must create mirrors");
    let spec = GpuSpec::default_sim();
    let sim = Simulator::new(spec.clone(), CostModel::default());
    let balancer =
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None };
    let pool = Pool::new(4);

    // Fixed per-partition frontier: every master, so boundary edges fire.
    let fronts: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| (0..p.num_masters as u32).collect())
        .collect();
    let mut gpus: Vec<DistGpu> = dg
        .parts
        .iter()
        .zip(plan.new_states())
        .map(|(p, st)| DistGpu {
            st,
            scratch: RoundScratch::for_vertices(p.graph.num_vertices()),
        })
        .collect();
    let mut flows: Vec<Flow> = Vec::new();

    let round = |gpus: &mut Vec<DistGpu>, flows: &mut Vec<Flow>| -> u64 {
        // Reset labels + frontier so every superstep does identical work
        // (fill / clear / extend: no allocation once warmed).
        for (pi, s) in gpus.iter_mut().enumerate() {
            s.st.labels.fill(f32::INFINITY);
            s.st.active.clear();
            s.st.active.extend_from_slice(&fronts[pi]);
            for &l in &fronts[pi] {
                s.st.labels[l as usize] = 0.0;
            }
        }
        // Compute superstep: one in-place task per simulated GPU on the
        // shared pool; returning is the BSP barrier.
        superstep_mut(ExecMode::Parallel, &pool, gpus, &|pi, s: &mut DistGpu| {
            let lg = &dg.parts[pi].graph;
            let scan = lg.num_vertices() as u64;
            balancer.schedule_into_pooled(
                &s.st.active, lg, Direction::Push, &spec, scan,
                &mut s.scratch.sched, &pool,
            );
            sim.simulate_into_pooled(
                &s.scratch.sched.sched, true, &mut s.scratch.sim, &pool,
            );
            for &v in &s.st.active {
                let dv = s.st.labels[v as usize];
                let (dsts, ws) = lg.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    let cand = dv + w;
                    if cand < s.st.labels[dst as usize] {
                        s.st.labels[dst as usize] = cand;
                        s.scratch.next.push(dst);
                    }
                }
            }
            s.scratch.next.take_sorted_into(&mut s.st.changed);
        });
        // Gluon sync over the precomputed schedules.
        flows.clear();
        plan.reduce_min(gpus, flows) + plan.broadcast_min(gpus, flows)
    };

    // Warm: first supersteps grow every buffer (including worker-claimed
    // chunk arenas) to capacity.
    let warm_bytes = round(&mut gpus, &mut flows);
    assert!(warm_bytes > 0, "warmup superstep must exchange bytes");
    for _ in 0..2 {
        round(&mut gpus, &mut flows);
    }

    let before = allocs_on_this_thread();
    for _ in 0..10 {
        round(&mut gpus, &mut flows);
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "steady-state distributed supersteps allocated on the submitting \
         thread"
    );
}

#[test]
fn counting_allocator_actually_counts() {
    // Sanity for the gate itself: an allocation on this thread is visible.
    let before = allocs_on_this_thread();
    let v: Vec<u64> = Vec::with_capacity(1024);
    std::hint::black_box(&v);
    let after = allocs_on_this_thread();
    assert!(after > before, "allocation not observed ({before} -> {after})");
}
