//! CLI contract: `alb run` / `alb sweep` emit stable JSON key sets
//! (schema snapshots — consumers parse these artifacts, so key drift is a
//! breaking change that must be deliberate), and invalid flag values exit
//! nonzero with the valid range on stderr.

use std::path::PathBuf;
use std::process::Command;

fn alb_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_alb"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alb-cli-{}-{name}", std::process::id()))
}

/// Keys of a pretty-printed `metrics::Json` object at `indent` levels
/// (2 spaces per level), in file order (== sorted: BTreeMap writer).
fn keys_at(json: &str, indent: usize) -> Vec<String> {
    let pad = "  ".repeat(indent);
    let mut out = Vec::new();
    for line in json.lines() {
        let Some(rest) = line.strip_prefix(&pad) else { continue };
        if rest.starts_with(' ') || !rest.starts_with('"') {
            continue; // deeper level or not a key line
        }
        if let Some((key, _)) = rest[1..].split_once('"') {
            out.push(key.to_string());
        }
    }
    out
}

// ------------------------------------------------------------ run schema

#[test]
fn run_single_gpu_json_schema() {
    let path = tmp("run1.json");
    let out = alb_bin()
        .args([
            "run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
            "--json", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        keys_at(&json, 1),
        [
            "app", "converged", "edges", "framework", "gpu_spec", "gpus",
            "graph_cache_hit", "input", "labels_hash", "lb_rounds", "reorder",
            "rounds", "schema_version", "seed", "sim_threads", "simulated_ms",
            "source",
        ],
        "single-GPU `alb run --json` schema drifted"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn run_multi_gpu_json_schema() {
    let path = tmp("run4.json");
    let out = alb_bin()
        .args([
            "run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
            "--gpus", "4", "--policy", "cvc", "--json", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        keys_at(&json, 1),
        [
            "app", "checkpoint_bytes", "comm_bytes", "comm_bytes_inter",
            "comm_bytes_intra", "comm_ms", "comp_ms", "converged", "exec",
            "framework", "gpu_spec", "gpus", "graph_cache_hit", "input",
            "labels_hash", "os_threads", "per_gpu_wall_ms", "policy",
            "recoveries", "reorder", "replayed_rounds", "retry_count",
            "rounds", "schema_version", "seed", "sim_threads", "simulated_ms",
            "source",
        ],
        "multi-GPU `alb run --json` schema drifted"
    );
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------- sweep schema

#[test]
fn sweep_artifact_json_schema_and_list() {
    // --list enumerates without running.
    let out = alb_bin()
        .args(["sweep", "--smoke", "--list", "--apps", "bfs", "--inputs", "road-s"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bfs/road-s/twc/-/1"), "{stdout}");
    assert!(stdout.contains("bfs/road-s/alb/cvc/4"), "{stdout}");
    assert!(stdout.contains("4 cells"), "{stdout}");

    // A filtered tiny sweep writes the stable artifact schema.
    let path = tmp("sweep.json");
    let out = alb_bin()
        .args([
            "sweep", "--smoke", "--apps", "bfs", "--inputs", "road-s",
            "--scale-delta", "-4", "--sim-threads", "2", "--resume", "false",
            "--out", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        keys_at(&json, 1),
        ["campaign", "cells", "scale_delta", "schema_version", "seed", "smoke"],
        "CAMPAIGN.json top-level schema drifted"
    );
    let mut cell_keys = keys_at(&json, 3);
    let per_cell = 22;
    assert_eq!(cell_keys.len() % per_cell, 0, "ragged cell objects");
    cell_keys.truncate(per_cell);
    assert_eq!(
        cell_keys,
        [
            "adaptive_threshold_final", "app", "balancer", "comm_bytes",
            "comm_bytes_inter", "comm_bytes_intra", "converged", "fault",
            "gpus", "host_ms", "id", "imbalance_factor", "input",
            "labels_hash", "lb_rounds", "policy", "recoveries",
            "replayed_rounds", "retry_count", "rounds", "simulated_ms",
            "total_cycles",
        ],
        "CAMPAIGN.json cell schema drifted"
    );
    // The human summary table is printed alongside the artifact.
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("cell"), "{stdout}");
    assert!(stdout.contains("4 cells (4 executed, 0 resumed)"), "{stdout}");
    let _ = std::fs::remove_file(&path);
}

// -------------------------------------------------- invalid-value errors

fn expect_failure(args: &[&str], needle: &str) {
    let out = alb_bin().args(args).output().unwrap();
    assert!(
        !out.status.success(),
        "`alb {}` should exit nonzero",
        args.join(" ")
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains(needle),
        "`alb {}` stderr should name the valid values ({needle:?}), got: {stderr}",
        args.join(" ")
    );
}

#[test]
fn invalid_values_exit_nonzero_with_valid_range() {
    // --exec lists every accepted spelling.
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--gpus", "2", "--exec", "bogus"],
        "parallel, par, sequential, seq",
    );
    // --sim-threads names the 1..=512 range (run and sweep alike).
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--sim-threads", "0"],
        "1..=512",
    );
    expect_failure(&["sweep", "--smoke", "--sim-threads", "abc"], "1..=512");
    // Sweep dimension filters list the valid sets.
    expect_failure(&["sweep", "--smoke", "--apps", "bogus"], "sssp-delta");
    expect_failure(&["sweep", "--smoke", "--inputs", "bogus"], "rmat18");
    expect_failure(
        &["sweep", "--smoke", "--balancers", "bogus"],
        "vertex, twc, edge-lb, alb, enterprise, adaptive, auto",
    );
    expect_failure(&["sweep", "--smoke", "--policies", "bogus"], "oec, iec, cvc");
    expect_failure(&["sweep", "--smoke", "--gpus", "0"], "1..=64");
    expect_failure(&["sweep", "--smoke", "--resume", "maybe"], "--resume true|false");
    // `alb run --balancer` names the strategy list too.
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--balancer", "bogus"],
        "vertex, twc, edge-lb, alb, enterprise, adaptive, auto",
    );
    // --reorder lists the ordering set; --graph-cache rejects .albg files.
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--reorder", "bogus"],
        "none, degree, rcm",
    );
    expect_failure(
        &["run", "--app", "bfs", "--input", "fake.albg", "--graph-cache",
          "/tmp/alb-cli-nocache"],
        "named input presets",
    );
    // --faults names the plan grammar and presets; --checkpoint-every names
    // the accepted interval; both are distributed-only flags.
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--gpus", "4", "--faults", "bogus"],
        "gpu-death@R:G",
    );
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--gpus", "4", "--checkpoint-every", "abc"],
        "bad --checkpoint-every",
    );
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--faults", "chaos"],
        "--gpus > 1",
    );
    // The sweep fault axis only takes named presets (ids must stay stable).
    expect_failure(&["sweep", "--smoke", "--faults", "bogus"], "gpu-death");
    // `alb run --max-rounds` names the accepted range.
    expect_failure(
        &["run", "--app", "bfs", "--input", "road-s", "--scale-delta", "-4",
          "--max-rounds", "0"],
        "1..=4294967295",
    );
}

#[test]
fn serve_invalid_values_exit_nonzero_with_valid_range() {
    // Every serve flag fails CLI-grade: the error names the full valid set.
    expect_failure(&["serve"], "valid presets");
    expect_failure(
        &["serve", "--graph", "road-s", "--port", "70000"],
        "0..=65535",
    );
    expect_failure(
        &["serve", "--graph", "road-s", "--max-inflight", "0"],
        "1..=1024",
    );
    expect_failure(
        &["serve", "--graph", "road-s", "--cache-entries", "9999999"],
        "0..=1048576",
    );
    expect_failure(
        &["serve", "--graph", "road-s", "--max-rounds", "abc"],
        "1..=4294967295",
    );
    expect_failure(
        &["serve", "--graph", "road-s", "--balancer", "bogus"],
        "vertex, twc, edge-lb, alb, enterprise, adaptive, auto",
    );
    expect_failure(&["serve", "--graph", "road-s", "--gpu-spec", "bogus"], "sim-default");
    expect_failure(&["serve", "--graph", "road-s", "--framework", "bogus"], "dirgl-alb");
    expect_failure(&["serve", "--graph", "road-s", "--sim-threads", "0"], "1..=512");
}

// ------------------------------------------------------- adaptive gate

#[test]
fn sweep_check_adaptive_gates_end_to_end() {
    // The CLI path CI's adaptive-gate job drives: a default-scale sweep on
    // a hub preset (where the LB kernel actually fires) with the runtime
    // controller racing a static strategy, strict gate on.
    let path = tmp("adaptive-gate.json");
    let out = alb_bin()
        .args([
            "sweep", "--apps", "bfs", "--inputs", "rmat18", "--gpus", "1",
            "--balancers", "twc,adaptive", "--sim-threads", "2",
            "--resume", "false", "--check-adaptive",
            "--out", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("adaptive gate ok"), "{stdout}");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------- chaos gate

#[test]
fn sweep_check_faults_gates_end_to_end() {
    // The CLI path CI's chaos-gate job drives: a faulted sweep whose every
    // faulty cell must recover to its fault-free twin's labels, strict
    // gate on. `none` rides along to supply the twins.
    let path = tmp("chaos-gate.json");
    let out = alb_bin()
        .args([
            "sweep", "--apps", "bfs", "--inputs", "rmat18",
            "--balancers", "alb", "--policies", "cvc", "--gpus", "4",
            "--faults", "none,gpu-death", "--scale-delta", "-4",
            "--sim-threads", "2", "--resume", "false", "--check-faults",
            "--out", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("fault gate ok"), "{stdout}");
    // The faulty cell is a first-class row with its own id.
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.contains("bfs/rmat18/alb/cvc/4/gpu-death"), "{json}");
    let _ = std::fs::remove_file(&path);
}
