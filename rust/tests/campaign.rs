//! Campaign-runner integration: determinism, resume, golden structure.
//!
//! These run the real smoke matrix in-process at a reduced scale
//! (`scale_delta = -4`, like the other integration suites) so the whole
//! pipeline — spec enumeration, engine/coordinator execution, artifact
//! write/read, golden comparison, and the repro invariants — is exercised
//! by tier-1 `cargo test`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use alb_graph::campaign::{artifact, run_sweep, CampaignSpec, CellResult};
use alb_graph::repro;

const DELTA: i32 = -4; // small but non-trivial inputs for CI

fn tiny_smoke() -> CampaignSpec {
    let mut s = CampaignSpec::smoke();
    s.scale_delta = DELTA;
    s.sim_threads = 2;
    s
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alb-campaign-{}-{name}", std::process::id()))
}

/// Everything but the machine-dependent wall clock.
fn deterministic_view(r: &CellResult) -> CellResult {
    CellResult { host_ms: 0.0, ..r.clone() }
}

#[test]
fn smoke_sweep_is_deterministic_resumable_and_invariant() {
    let spec = tiny_smoke();
    let n_cells = spec.cells().len();
    assert_eq!(n_cells, 32, "smoke matrix size is pinned by the golden");

    // Fresh run, checkpointed to disk.
    let p = tmp("fresh.json");
    let first = run_sweep(&spec, &HashMap::new(), Some(&p), |_, _| {}).unwrap();
    assert_eq!(first.executed, n_cells);
    assert_eq!(first.skipped, 0);

    // The paper's golden expectations hold on any machine.
    repro::check_campaign_invariants(&first.results).unwrap();

    // A second fresh run reproduces every deterministic field bit-for-bit.
    let second = run_sweep(&spec, &HashMap::new(), None, |_, _| {}).unwrap();
    for (a, b) in first.results.iter().zip(&second.results) {
        assert_eq!(deterministic_view(a), deterministic_view(b), "{}", a.id);
    }

    // Resuming from the artifact skips every cell and rewrites the file
    // byte-identically (host_ms is carried verbatim).
    let before = std::fs::read_to_string(&p).unwrap();
    let prev = artifact::read(&p).unwrap();
    assert!(prev.matches_spec(&spec));
    let prior: HashMap<String, CellResult> =
        prev.cells.into_iter().map(|c| (c.id.clone(), c)).collect();
    let resumed = run_sweep(&spec, &prior, Some(&p), |_, _| {}).unwrap();
    assert_eq!(resumed.executed, 0);
    assert_eq!(resumed.skipped, n_cells);
    assert_eq!(resumed.results, first.results);
    assert_eq!(std::fs::read_to_string(&p).unwrap(), before);

    // A fully-seeded golden (the first artifact itself) passes the check.
    let golden = artifact::parse(&before);
    let rep = artifact::check_golden(&first.results, &golden, "first-run").unwrap();
    assert_eq!(rep.seeded, n_cells);
    assert_eq!(rep.unseeded, 0);

    let _ = std::fs::remove_file(&p);
}

#[test]
fn partial_artifact_resumes_only_missing_cells() {
    let spec = tiny_smoke();
    let mut bfs_only = tiny_smoke();
    bfs_only.filter_apps("bfs").unwrap();
    let n_bfs = bfs_only.cells().len();
    let n_all = spec.cells().len();
    assert!(n_bfs > 0 && n_bfs < n_all);

    // Seed an artifact with just the bfs cells...
    let p = tmp("partial.json");
    run_sweep(&bfs_only, &HashMap::new(), Some(&p), |_, _| {}).unwrap();

    // ...then run the full smoke spec resuming from it: only the missing
    // cells execute, and the merged result equals a fresh full run on
    // every deterministic field.
    let prior: HashMap<String, CellResult> = artifact::read(&p)
        .unwrap()
        .cells
        .into_iter()
        .map(|c| (c.id.clone(), c))
        .collect();
    assert_eq!(prior.len(), n_bfs);
    let merged = run_sweep(&spec, &prior, Some(&p), |_, _| {}).unwrap();
    assert_eq!(merged.skipped, n_bfs);
    assert_eq!(merged.executed, n_all - n_bfs);

    let fresh = run_sweep(&spec, &HashMap::new(), None, |_, _| {}).unwrap();
    for (a, b) in merged.results.iter().zip(&fresh.results) {
        assert_eq!(deterministic_view(a), deterministic_view(b), "{}", a.id);
    }
    let _ = std::fs::remove_file(&p);
}

#[test]
fn committed_golden_pins_the_smoke_matrix() {
    // The committed CAMPAIGN.golden.json must list exactly the smoke
    // enumeration, in order — this arms the structural half of the CI
    // golden gate inside tier-1 itself (the hash half is seeded from the
    // first sweep-smoke artifact; see DESIGN.md §11).
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(repro::CAMPAIGN_GOLDEN);
    let golden = artifact::read(&path).unwrap();
    assert_eq!(golden.schema_version, artifact::SCHEMA_VERSION);
    assert!(golden.smoke, "golden must record the smoke subset");

    let spec = CampaignSpec::smoke();
    assert_eq!(golden.seed, spec.seed);
    assert_eq!(golden.scale_delta, spec.scale_delta as i64);
    let want: Vec<String> = spec.cells().iter().map(|c| c.id()).collect();
    let got: Vec<String> = golden.cells.iter().map(|c| c.id.clone()).collect();
    assert_eq!(got, want, "golden cell ids must match the smoke enumeration");
}

#[test]
fn invariant_checker_rejects_divergent_labels() {
    // Two cells differing only in balancer but hashing differently must
    // trip the balancer-independence invariant.
    let mk = |balancer: &str, hash: &str| CellResult {
        id: format!("bfs/rmat18/{balancer}/-/1"),
        app: "bfs".into(),
        input: "rmat18".into(),
        balancer: balancer.into(),
        policy: "-".into(),
        gpus: 1,
        labels_hash: hash.into(),
        ..CellResult::default()
    };
    let ok = vec![mk("twc", "aa"), mk("alb", "aa")];
    repro::check_campaign_invariants(&ok).unwrap();
    let bad = vec![mk("twc", "aa"), mk("alb", "bb")];
    let err = repro::check_campaign_invariants(&bad).unwrap_err();
    assert!(err.contains("balancer-independence"), "{err}");

    // And bfs cells of the same input must agree across GPU counts.
    let dist = CellResult {
        id: "bfs/rmat18/twc/cvc/4".into(),
        policy: "cvc".into(),
        gpus: 4,
        labels_hash: "cc".into(),
        ..mk("twc", "cc")
    };
    let bad = vec![mk("twc", "aa"), dist];
    let err = repro::check_campaign_invariants(&bad).unwrap_err();
    assert!(err.contains("scale-out"), "{err}");
}

#[test]
fn adaptive_dominance_invariant_and_strict_gate() {
    let mk = |balancer: &str, input: &str, cycles: u64| CellResult {
        id: format!("bfs/{input}/{balancer}/-/1"),
        app: "bfs".into(),
        input: input.into(),
        balancer: balancer.into(),
        policy: "-".into(),
        gpus: 1,
        labels_hash: "aa".into(),
        total_cycles: cycles,
        ..CellResult::default()
    };

    // Adaptive tying one static strategy and beating another passes both
    // the always-on invariant and the strict gate.
    let winning = vec![
        mk("twc", "rmat18", 100),
        mk("alb", "rmat18", 90),
        mk("adaptive", "rmat18", 90),
    ];
    repro::check_campaign_invariants(&winning).unwrap();
    repro::check_adaptive_dominance(&winning).unwrap();

    // Losing on a high-imbalance input trips the always-on invariant, and
    // the error names both cells.
    let losing_hub = vec![mk("twc", "rmat18", 100), mk("adaptive", "rmat18", 101)];
    let err = repro::check_campaign_invariants(&losing_hub).unwrap_err();
    assert!(err.contains("adaptive-dominance"), "{err}");
    assert!(err.contains("bfs/rmat18/adaptive/-/1"), "{err}");

    // Losing on a balanced input is out of the invariant's scope (the
    // controller targets skew) but fails the opt-in strict gate.
    let losing_flat = vec![mk("twc", "orkut-s", 100), mk("adaptive", "orkut-s", 101)];
    repro::check_campaign_invariants(&losing_flat).unwrap();
    let err = repro::check_adaptive_dominance(&losing_flat).unwrap_err();
    assert!(err.contains("ADAPTIVE GATE FAILED"), "{err}");

    // `auto` cells never count as a static side: auto may itself resolve
    // to adaptive, so comparing the two would be self-referential.
    let auto = vec![mk("auto", "rmat18", 1), mk("adaptive", "rmat18", 2)];
    repro::check_campaign_invariants(&auto).unwrap();
    repro::check_adaptive_dominance(&auto).unwrap();
}

#[test]
fn adaptive_gate_passes_on_a_real_high_imbalance_sweep() {
    // The in-process twin of CI's adaptive-gate job: every balancer on a
    // hub preset at default scale — the regime where the LB kernel fires
    // and the controller earns its keep (at reduced scale the inspector is
    // dormant and the comparison is vacuous). Adaptive must match or beat
    // each static strategy in cycles while producing identical labels.
    let mut spec = CampaignSpec::full();
    spec.sim_threads = 2;
    spec.filter_apps("bfs").unwrap();
    spec.filter_inputs("rmat18").unwrap();
    spec.filter_gpus("1").unwrap();
    let out = run_sweep(&spec, &HashMap::new(), None, |_, _| {}).unwrap();
    assert_eq!(out.results.len(), spec.cells().len());
    repro::check_campaign_invariants(&out.results).unwrap();
    repro::check_adaptive_dominance(&out.results).unwrap();
}

/// Adversarial insertion-order determinism: the resume map handed to
/// `run_sweep` is a `HashMap`, whose iteration order depends on the
/// per-instance hasher seed and insertion history. Feed the same cells in
/// two opposite insertion orders and the checkpoint artifacts must still
/// be byte-identical — the sorted writer, not the map, owns the output
/// ordering. (The static side of this invariant is lint rule D002; see
/// DESIGN.md §15.)
#[test]
fn artifact_bytes_are_independent_of_resume_map_insertion_order() {
    let mut spec = tiny_smoke();
    spec.filter_apps("bfs").unwrap();
    let n_cells = spec.cells().len();

    let p0 = tmp("order-seed.json");
    let fresh = run_sweep(&spec, &HashMap::new(), Some(&p0), |_, _| {}).unwrap();
    assert_eq!(fresh.executed, n_cells);
    let seed_bytes = std::fs::read_to_string(&p0).unwrap();

    let cells = artifact::read(&p0).unwrap().cells;
    assert_eq!(cells.len(), n_cells);

    let mut fwd: HashMap<String, CellResult> = HashMap::new();
    for c in &cells {
        fwd.insert(c.id.clone(), c.clone());
    }
    let mut rev: HashMap<String, CellResult> = HashMap::new();
    for c in cells.iter().rev() {
        rev.insert(c.id.clone(), c.clone());
    }

    let pa = tmp("order-fwd.json");
    let a = run_sweep(&spec, &fwd, Some(&pa), |_, _| {}).unwrap();
    assert_eq!(a.skipped, n_cells);

    let pb = tmp("order-rev.json");
    let b = run_sweep(&spec, &rev, Some(&pb), |_, _| {}).unwrap();
    assert_eq!(b.skipped, n_cells);

    let bytes_a = std::fs::read_to_string(&pa).unwrap();
    let bytes_b = std::fs::read_to_string(&pb).unwrap();
    assert_eq!(
        bytes_a, bytes_b,
        "resume-map insertion order leaked into the artifact"
    );
    assert_eq!(
        bytes_a, seed_bytes,
        "resumed artifact drifted from the fresh artifact"
    );

    for p in [&p0, &pa, &pb] {
        let _ = std::fs::remove_file(p);
    }
}
