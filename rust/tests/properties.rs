//! Property-based tests over randomized inputs (hand-rolled trials on the
//! deterministic in-tree RNG — the vendored crate set has no proptest).
//!
//! Invariants checked, each over many random graphs/configurations:
//! * work conservation: every strategy schedules each active edge once;
//! * inspector partition: huge + rest == active, threshold respected;
//! * prefix/binary-search inverse: edge id -> source recovers the owner;
//! * LB block-edge accounting sums to total for both distributions;
//! * partition correctness under every policy and part count;
//! * all balancers and GPU counts converge to oracle labels;
//! * simulator monotonicity: more edges never cost fewer cycles.

use alb_graph::apps::engine::{run, EngineConfig};
use alb_graph::apps::{bfs, App};
use alb_graph::coordinator::{run_distributed, ClusterConfig};
use alb_graph::gpu::{CostModel, GpuSpec, Simulator};
use alb_graph::graph::rng::Rng;
use alb_graph::graph::{CsrGraph, EdgeList};
use alb_graph::lb::{alb, schedule::Distribution, Balancer, Direction};
use alb_graph::partition::{partition, Policy};

/// Random graph: n vertices, ~m edges, with probability `hub_p` one vertex
/// is force-fed a huge out-degree (the ALB trigger regime).
fn random_graph(rng: &mut Rng, max_n: u64, hub: bool) -> CsrGraph {
    let n = (2 + rng.gen_range(max_n)) as u32;
    let m = rng.gen_range(8 * n as u64 + 1);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        let s = rng.gen_range(n as u64) as u32;
        let d = rng.gen_range(n as u64) as u32;
        el.push(s, d, (1 + rng.gen_range(16)) as f32);
    }
    if hub {
        let hub_deg = 3072 + rng.gen_range(4096);
        for _ in 0..hub_deg {
            el.push(0, rng.gen_range(n as u64) as u32, 1.0);
        }
    }
    CsrGraph::from_edge_list(&el)
}

fn random_active(rng: &mut Rng, g: &CsrGraph) -> Vec<u32> {
    let mut active: Vec<u32> = (0..g.num_vertices() as u32)
        .filter(|_| rng.gen_bool(0.6))
        .collect();
    if active.is_empty() {
        active.push(0);
    }
    active
}

#[test]
fn prop_work_conservation_all_balancers() {
    let mut rng = Rng::new(1001);
    let spec = GpuSpec::default_sim();
    for trial in 0..30 {
        let g = random_graph(&mut rng, 2000, trial % 3 == 0);
        let active = random_active(&mut rng, &g);
        let want: u64 = active.iter().map(|&v| g.out_degree(v)).sum();
        for b in [
            Balancer::Vertex,
            Balancer::Twc,
            Balancer::EdgeLb { distribution: Distribution::Cyclic },
            Balancer::EdgeLb { distribution: Distribution::Blocked },
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
            Balancer::Alb { distribution: Distribution::Blocked, threshold: Some(64) },
        ] {
            let s = b.schedule(&active, &g, Direction::Push, &spec, 0);
            assert_eq!(s.total_edges(), want, "trial {trial} {}", b.name());
        }
    }
}

#[test]
fn prop_inspector_partition_is_exact() {
    let mut rng = Rng::new(2002);
    let spec = GpuSpec::default_sim();
    for trial in 0..40 {
        let g = random_graph(&mut rng, 3000, trial % 2 == 0);
        let active = random_active(&mut rng, &g);
        let threshold = 1 + rng.gen_range(5000);
        let ins = alb::inspect(&active, &g, Direction::Push, &spec, threshold);
        assert_eq!(ins.huge.len() + ins.rest.len(), active.len());
        for &v in &ins.huge {
            assert!(g.out_degree(v) >= threshold);
        }
        for item in &ins.rest {
            assert!(item.degree < threshold);
        }
        // Prefix is the inclusive cumsum of huge degrees, in order.
        let mut run = 0;
        for (i, &v) in ins.huge.iter().enumerate() {
            run += g.out_degree(v);
            assert_eq!(ins.prefix[i], run);
        }
    }
}

#[test]
fn prop_binary_search_inverts_prefix() {
    let mut rng = Rng::new(3003);
    for _ in 0..50 {
        let h = 1 + rng.gen_range(300) as usize;
        let mut prefix = Vec::with_capacity(h);
        let mut run = 0u64;
        for _ in 0..h {
            run += 1 + rng.gen_range(1000);
            prefix.push(run);
        }
        // For random edge ids, the owner found by binary search must bound
        // the id within its [start, end) range.
        for _ in 0..100 {
            let eid = rng.gen_range(run);
            let idx = prefix.partition_point(|&p| p <= eid);
            let start = if idx == 0 { 0 } else { prefix[idx - 1] };
            assert!(start <= eid && eid < prefix[idx]);
        }
    }
}

#[test]
fn prop_lb_block_edges_sum_to_total() {
    let mut rng = Rng::new(4004);
    let spec = GpuSpec::default_sim();
    let sim = Simulator::new(spec.clone(), CostModel::default());
    for _ in 0..25 {
        let g = random_graph(&mut rng, 1000, true);
        let active = random_active(&mut rng, &g);
        for dist in [Distribution::Cyclic, Distribution::Blocked] {
            let s = Balancer::EdgeLb { distribution: dist }.schedule(
                &active, &g, Direction::Push, &spec, 0,
            );
            let total = s.total_edges();
            let r = sim.simulate(&s, true);
            if let Some(k) = r.kernels.iter().find(|k| k.label == "lb") {
                assert_eq!(
                    k.block_edges.iter().sum::<u64>(),
                    total,
                    "{dist:?}"
                );
            } else {
                assert_eq!(total, 0);
            }
        }
    }
}

#[test]
fn prop_partition_edge_multiset_preserved() {
    let mut rng = Rng::new(5005);
    for trial in 0..12 {
        let g = random_graph(&mut rng, 800, trial % 2 == 0);
        let k = 1 + rng.gen_range(7) as u32;
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let dg = partition(&g, k, policy);
            let local_edges: usize =
                dg.parts.iter().map(|p| p.graph.num_edges()).sum();
            assert_eq!(local_edges, g.num_edges(), "{policy:?} k={k}");
            // Every vertex mastered exactly once.
            let masters: usize = dg.parts.iter().map(|p| p.num_masters).sum();
            assert_eq!(masters, g.num_vertices());
        }
    }
}

#[test]
fn prop_bfs_converges_to_oracle_everywhere() {
    let mut rng = Rng::new(6006);
    for trial in 0..8 {
        let g = random_graph(&mut rng, 600, trial % 2 == 0);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        // Single GPU, every balancer.
        for b in [
            Balancer::Twc,
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
            Balancer::EdgeLb { distribution: Distribution::Blocked },
        ] {
            let cfg = EngineConfig { balancer: b, ..EngineConfig::default() };
            let r = run(App::Bfs, &mut g.clone(), src, &cfg, None).unwrap();
            assert_eq!(r.labels, want, "trial {trial}");
        }
        // Distributed, random k and policy.
        let k = 1 + rng.gen_range(5) as u32;
        let policy = [Policy::Oec, Policy::Iec, Policy::Cvc]
            [rng.gen_range(3) as usize];
        let cluster = ClusterConfig {
            policy,
            ..ClusterConfig::bridges(k)
        };
        let r = run_distributed(App::Bfs, &g, src, &EngineConfig::default(),
                                &cluster, None)
            .unwrap();
        assert_eq!(r.labels, want, "trial {trial} dist k={k} {policy:?}");
    }
}

#[test]
fn prop_simulator_monotone_in_work() {
    let mut rng = Rng::new(7007);
    let spec = GpuSpec::default_sim();
    let sim = Simulator::new(spec.clone(), CostModel::default());
    for _ in 0..20 {
        let g = random_graph(&mut rng, 1500, true);
        let mut active = random_active(&mut rng, &g);
        let s_small = Balancer::Twc.schedule(&active, &g, Direction::Push, &spec, 0);
        // Superset of the active set -> at least as many cycles.
        let mut extra: Vec<u32> = (0..g.num_vertices() as u32).collect();
        extra.retain(|v| !active.contains(v));
        active.extend(extra);
        let s_big = Balancer::Twc.schedule(&active, &g, Direction::Push, &spec, 0);
        let c_small = sim.simulate(&s_small, true).total_cycles;
        let c_big = sim.simulate(&s_big, true).total_cycles;
        assert!(c_big >= c_small, "{c_big} < {c_small}");
    }
}

#[test]
fn prop_alb_vs_twc_ordering_stable_under_cost_perturbation() {
    // The docs claim the reproduced *ratios* survive +-2x perturbations of
    // the cost constants (every strategy is charged through the same
    // model). Verify the headline ordering (ALB <= TWC cycles on a
    // hub-dominated input) under randomized cost models.
    let mut rng = Rng::new(9009);
    let g = {
        let mut el = EdgeList::new(20_000);
        for i in 0..60_000u32 {
            el.push(0, 1 + (i % 19_999), 1.0); // hub: 60k edges
        }
        for v in 1..2_000u32 {
            el.push(v, v + 1, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    };
    let spec = GpuSpec::default_sim();
    let perturb = |rng: &mut Rng, base: u64| -> u64 {
        let f = 0.5 + rng.gen_f64() * 1.5; // [0.5, 2.0)
        ((base as f64 * f) as u64).max(1)
    };
    for trial in 0..10 {
        let base = CostModel::default();
        let cost = CostModel {
            cycles_edge: perturb(&mut rng, base.cycles_edge),
            cycles_atomic: perturb(&mut rng, base.cycles_atomic),
            cycles_mem_hit: perturb(&mut rng, base.cycles_mem_hit),
            cycles_mem_miss: perturb(&mut rng, base.cycles_mem_miss),
            cycles_launch: perturb(&mut rng, base.cycles_launch),
            cycles_scan_vertex: perturb(&mut rng, base.cycles_scan_vertex),
            cycles_prefix_per_item: perturb(&mut rng, base.cycles_prefix_per_item),
            lb_warp_step_sample_cap: base.lb_warp_step_sample_cap,
            serial_kernels: base.serial_kernels,
        };
        let mk = |b: Balancer| EngineConfig {
            balancer: b,
            cost: cost.clone(),
            spec: spec.clone(),
            ..EngineConfig::default()
        };
        let twc = run(App::Bfs, &mut g.clone(), 0, &mk(Balancer::Twc), None).unwrap();
        let alb = run(
            App::Bfs,
            &mut g.clone(),
            0,
            &mk(Balancer::Alb { distribution: Distribution::Cyclic, threshold: None }),
            None,
        )
        .unwrap();
        assert_eq!(twc.labels, alb.labels);
        assert!(
            alb.total_cycles < twc.total_cycles,
            "trial {trial}: ordering flipped ({} vs {}) under {cost:?}",
            alb.total_cycles,
            twc.total_cycles
        );
    }
}

#[test]
fn prop_threshold_extremes_bracket_alb() {
    // threshold=0 (all LB) and threshold=MAX (all TWC) are the paper's §4.2
    // extremes; any threshold in between must schedule the same total work.
    let mut rng = Rng::new(8008);
    let spec = GpuSpec::default_sim();
    for _ in 0..15 {
        let g = random_graph(&mut rng, 1000, true);
        let active = random_active(&mut rng, &g);
        let want: u64 = active.iter().map(|&v| g.out_degree(v)).sum();
        for threshold in [0u64, 1, 32, 3072, u64::MAX] {
            let s = alb::schedule(
                &active, &g, Direction::Push, &spec,
                Distribution::Cyclic, threshold, 0,
            );
            assert_eq!(s.total_edges(), want);
            if threshold == 0 {
                assert!(s.twc.is_empty());
            }
            if threshold == u64::MAX {
                assert!(s.lb.is_none());
            }
        }
    }
}
