//! Fault-tolerance gates (DESIGN.md §14).
//!
//! 1. **Recovery parity matrix**: for every (app ∈ {bfs, sssp, kcore},
//!    policy ∈ {oec, iec, cvc}, fault plan) cell on a high-imbalance
//!    input, the recovered run's final labels must be bit-identical to the
//!    fault-free run's — GPU death replays from checkpoint onto a
//!    re-partitioned survivor set, corruption/drops retry the exchange,
//!    and none of it may change a single label bit.
//! 2. **Recovery-metric determinism**: recoveries, replayed rounds, retry
//!    counts, checkpoint bytes, and modeled cycles are simulation outputs,
//!    so they must be exactly reproducible across `sim_threads ∈ {1,2,4}`.
//! 3. **Elastic soak**: a long-running high-diameter run survives a
//!    cascade of deaths (8 → 5 GPUs) interleaved with transient faults,
//!    across checkpoint cadences, and still lands on the fault-free
//!    fixpoint every time.
//! 4. **Legality**: pr (always) and cc (under gpu-death) are rejected
//!    loudly, not silently mis-recovered.

use alb_graph::apps::engine::EngineConfig;
use alb_graph::apps::App;
use alb_graph::comm::fault::FaultPlan;
use alb_graph::coordinator::{
    run_distributed, run_distributed_faulty, ClusterConfig, DistRunResult, FaultConfig,
};
use alb_graph::graph::inputs;
use alb_graph::partition::Policy;

const DELTA: i32 = -4; // small but non-trivial inputs for CI
const SEED: u64 = 42;

fn cfg() -> EngineConfig {
    EngineConfig { max_rounds: 1_000_000, ..EngineConfig::default() }
}

fn faults(spec: &str, gpus: u32, every: u64) -> FaultConfig {
    FaultConfig {
        plan: FaultPlan::parse(spec, gpus, SEED).unwrap(),
        checkpoint_every: every,
        checkpoint_dir: None,
    }
}

fn bits(labels: &[f32]) -> Vec<u32> {
    labels.iter().map(|x| x.to_bits()).collect()
}

fn run_faulty(
    app: App,
    input: &str,
    policy: Policy,
    gpus: u32,
    fc: &FaultConfig,
) -> DistRunResult {
    let g = inputs::build(input, DELTA, SEED).unwrap();
    let src = inputs::source_vertex(input, &g);
    let cluster = ClusterConfig { policy, ..ClusterConfig::single_host(gpus) };
    run_distributed_faulty(app, &g, src, &cfg(), &cluster, None, fc).unwrap()
}

/// Gate 1: the full recovery parity matrix. Plans are explicit (fixed
/// rounds and links) so every fault demonstrably fires mid-run.
#[test]
fn recovered_labels_are_bit_identical_across_the_matrix() {
    let plans = [
        "gpu-death@2:1",
        "corrupt@1:0-1x2,corrupt@3:2-3x1",
        "drop@2:1-2x2,slow@1:0-2x3",
        "chaos",
    ];
    let input = "rmat18";
    let (mut total_recoveries, mut total_retries) = (0u64, 0u64);
    for app in [App::Bfs, App::Sssp, App::Kcore] {
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let g = inputs::build(input, DELTA, SEED).unwrap();
            let src = inputs::source_vertex(input, &g);
            let cluster = ClusterConfig { policy, ..ClusterConfig::single_host(4) };
            let base = run_distributed(app, &g, src, &cfg(), &cluster, None).unwrap();
            for plan in plans {
                let fc = faults(plan, 4, 2);
                let r = run_faulty(app, input, policy, 4, &fc);
                assert_eq!(
                    bits(&r.labels),
                    bits(&base.labels),
                    "{}/{}/{plan}: recovered labels diverged from fault-free",
                    app.name(),
                    policy.name(),
                );
                assert!(r.converged, "{}/{}/{plan}: must still converge", app.name(), policy.name());
                assert!(r.checkpoint_bytes > 0, "checkpointing was on");
                total_recoveries += r.recoveries as u64;
                total_retries += r.retry_count;
            }
        }
    }
    // The matrix as a whole must actually have exercised both recovery
    // mechanisms, or the parity assertions above were vacuous.
    assert!(total_recoveries > 0, "no plan killed a GPU — fault injection is dead code");
    assert!(total_retries > 0, "no plan forced an exchange retry");
}

/// Targeted: a mid-run GPU death on each app re-partitions onto survivors,
/// replays, and reports it in the metrics.
#[test]
fn gpu_death_recovers_and_reports_metrics() {
    for app in [App::Bfs, App::Sssp] {
        let g = inputs::build("rmat18", DELTA, SEED).unwrap();
        let src = inputs::source_vertex("rmat18", &g);
        let cluster = ClusterConfig::single_host(4);
        let base = run_distributed(app, &g, src, &cfg(), &cluster, None).unwrap();
        let r = run_faulty(app, "rmat18", Policy::Cvc, 4, &faults("gpu-death@2:1", 4, 2));
        assert_eq!(bits(&r.labels), bits(&base.labels), "{}", app.name());
        assert_eq!(r.recoveries, 1, "{}", app.name());
        assert!(r.replayed_rounds <= 2, "checkpoint cadence 2 bounds the replay");
        assert_eq!(r.retry_count, 0, "death is not an exchange retry");
    }
}

/// Gate 2: every recovery metric is bit-deterministic across the intra-GPU
/// simulation pool width.
#[test]
fn recovery_metrics_are_deterministic_across_sim_threads() {
    for app in [App::Bfs, App::Kcore] {
        let fingerprint = |threads: usize| {
            let g = inputs::build("rmat18", DELTA, SEED).unwrap();
            let src = inputs::source_vertex("rmat18", &g);
            let mut c = cfg();
            c.sim_threads = threads;
            let fc = faults("chaos", 4, 2);
            let r = run_distributed_faulty(
                app, &g, src, &c, &ClusterConfig::single_host(4), None, &fc,
            )
            .unwrap();
            (
                bits(&r.labels),
                r.rounds.len(),
                r.total_cycles,
                r.recoveries,
                r.replayed_rounds,
                r.retry_count,
                r.checkpoint_bytes,
                r.converged,
            )
        };
        let one = fingerprint(1);
        assert_eq!(one, fingerprint(2), "{}: sim_threads 2 diverged", app.name());
        assert_eq!(one, fingerprint(4), "{}: sim_threads 4 diverged", app.name());
    }
}

/// Gate 3: the elastic soak. A high-diameter run on 8 GPUs loses three of
/// them at different rounds (8 -> 7 -> 6 -> 5 survivors) with corruption
/// and drops in between; for every checkpoint cadence the survivors must
/// land on the fault-free fixpoint with all three deaths recovered.
#[test]
fn elastic_soak_survives_cascading_deaths() {
    let input = "road-s";
    let g = inputs::build(input, DELTA, SEED).unwrap();
    let src = inputs::source_vertex(input, &g);
    let base =
        run_distributed(App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(8), None).unwrap();
    let plan = "gpu-death@3:0,corrupt@4:1-2x2,gpu-death@6:4,drop@8:0-3x2,gpu-death@10:2";
    for every in [1, 2, 5] {
        let r = run_faulty(App::Bfs, input, Policy::Cvc, 8, &faults(plan, 8, every));
        assert_eq!(
            bits(&r.labels),
            bits(&base.labels),
            "cadence {every}: soak diverged from fault-free"
        );
        assert!(r.converged, "cadence {every}");
        assert_eq!(r.recoveries, 3, "cadence {every}: all three deaths must fire");
        assert!(r.retry_count >= 4, "cadence {every}: corrupt x2 + drop x2 retries");
        assert!(
            r.replayed_rounds <= 3 * every,
            "cadence {every}: replay is bounded by the checkpoint interval per death"
        );
    }
}

/// Zero-fault faulty runs cost nothing they shouldn't: same labels, rounds,
/// and cycles as `run_distributed`, zero recovery metrics.
#[test]
fn empty_plan_matches_run_distributed_bit_for_bit() {
    for app in [App::Bfs, App::Sssp, App::Cc, App::Kcore] {
        let g = inputs::build("rmat18", DELTA, SEED).unwrap();
        let src = inputs::source_vertex("rmat18", &g);
        let cluster = ClusterConfig::single_host(4);
        let base = run_distributed(app, &g, src, &cfg(), &cluster, None).unwrap();
        let r = run_faulty(app, "rmat18", Policy::Cvc, 4, &faults("none", 4, 0));
        assert_eq!(bits(&r.labels), bits(&base.labels), "{}", app.name());
        assert_eq!(r.rounds.len(), base.rounds.len(), "{}", app.name());
        assert_eq!(r.total_cycles, base.total_cycles, "{}", app.name());
        assert_eq!(
            (r.recoveries, r.replayed_rounds, r.retry_count),
            (0, 0, 0),
            "{}",
            app.name()
        );
    }
}

/// Gate 4: legality. The fault driver refuses the apps whose recovery
/// cannot be bit-exact, with errors that say why and what is valid.
#[test]
fn illegal_fault_configs_are_rejected_loudly() {
    let g = inputs::build("rmat18", DELTA, SEED).unwrap();
    let src = inputs::source_vertex("rmat18", &g);
    let cluster = ClusterConfig::single_host(4);

    let pr_err =
        run_distributed_faulty(App::Pr, &g, src, &cfg(), &cluster, None, &faults("drop", 4, 0))
            .unwrap_err()
            .to_string();
    assert!(pr_err.contains("pr"), "{pr_err}");
    assert!(pr_err.contains("bfs"), "error must list valid apps: {pr_err}");

    let cc_err = run_distributed_faulty(
        App::Cc, &g, src, &cfg(), &cluster, None, &faults("gpu-death", 4, 0),
    )
    .unwrap_err()
    .to_string();
    assert!(cc_err.contains("cc"), "{cc_err}");

    // cc without a death-bearing plan is legal.
    run_distributed_faulty(App::Cc, &g, src, &cfg(), &cluster, None, &faults("drop", 4, 0))
        .unwrap();
}
