//! PJRT integration: the AOT-compiled JAX/Pallas kernels loaded and
//! executed from Rust, checked against the native implementations.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use alb_graph::apps::engine::{run, ComputeMode, EngineConfig};
use alb_graph::apps::{App, ALL_APPS};
use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::gen::rmat::{self, RmatConfig};
use alb_graph::graph::CsrGraph;
use alb_graph::runtime::{PjrtRuntime, INF};

fn runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP pjrt tests: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn loads_all_artifact_kinds() {
    let Some(rt) = runtime() else { return };
    assert!(rt.num_kernels() >= 5, "expected all kernel variants compiled");
    assert_eq!(rt.platform(), "cpu");
    assert!(rt.max_relax_h() >= 256);
}

#[test]
fn edge_relax_matches_reference_semantics() {
    let Some(rt) = runtime() else { return };
    // Three huge vertices with degrees 5, 3, 2 -> prefix [5, 8, 10].
    let prefix = [5u32, 8, 10];
    let src_dist = [10.0f32, 20.0, 30.0];
    let edge_ids: Vec<u32> = (0..10).collect();
    let weights = vec![1.0f32; 10];
    let (src, cand) = rt.edge_relax(&prefix, &src_dist, &edge_ids, &weights).unwrap();
    assert_eq!(src, vec![0, 0, 0, 0, 0, 1, 1, 1, 2, 2]);
    let want: Vec<f32> = src.iter().map(|&s| src_dist[s as usize] + 1.0).collect();
    assert_eq!(cand, want);
}

#[test]
fn edge_relax_batches_larger_than_variant() {
    let Some(rt) = runtime() else { return };
    // 5000 edges forces multiple kernel invocations (b = 2048).
    let prefix = [5000u32];
    let src_dist = [7.0f32];
    let edge_ids: Vec<u32> = (0..5000).collect();
    let weights: Vec<f32> = (0..5000).map(|i| (i % 10) as f32).collect();
    let (src, cand) = rt.edge_relax(&prefix, &src_dist, &edge_ids, &weights).unwrap();
    assert_eq!(src.len(), 5000);
    assert!(src.iter().all(|&s| s == 0));
    for (i, &c) in cand.iter().enumerate() {
        assert_eq!(c, 7.0 + (i % 10) as f32);
    }
}

#[test]
fn edge_relax_infinite_source_stays_infinite() {
    let Some(rt) = runtime() else { return };
    let prefix = [4u32];
    let src_dist = [INF];
    let edge_ids = [0u32, 1, 2, 3];
    let weights = [1.0f32; 4];
    let (_, cand) = rt.edge_relax(&prefix, &src_dist, &edge_ids, &weights).unwrap();
    assert!(cand.iter().all(|&c| c >= INF));
}

#[test]
fn prefix_sum_matches_cumsum() {
    let Some(rt) = runtime() else { return };
    let degs: Vec<u32> = (1..=200).collect();
    let got = rt.prefix_sum(&degs).unwrap();
    let mut run = 0u64;
    for (i, &d) in degs.iter().enumerate() {
        run += d as u64;
        assert_eq!(got[i], run);
    }
}

#[test]
fn pr_pull_matches_native() {
    let Some(rt) = runtime() else { return };
    let ranks: Vec<f32> = (0..1000).map(|i| (i as f32 + 1.0) / 1000.0).collect();
    let degs: Vec<u32> = (0..1000).map(|i| i % 17).collect();
    let got = rt.pr_pull(&ranks, &degs, 0.85).unwrap();
    for i in 0..1000 {
        let want = 0.85 * ranks[i] / (degs[i].max(1) as f32);
        assert!((got[i] - want).abs() < 1e-6, "{i}: {} vs {want}", got[i]);
    }
}

#[test]
fn kcore_alive_matches_threshold() {
    let Some(rt) = runtime() else { return };
    let degs: Vec<u32> = (0..500).collect();
    let alive = rt.kcore_alive(&degs, 100).unwrap();
    for (i, &a) in alive.iter().enumerate() {
        assert_eq!(a, i >= 100);
    }
}

#[test]
fn twc_bin_matches_native_binning() {
    let Some(rt) = runtime() else { return };
    let degs: Vec<u32> = vec![0, 31, 32, 127, 128, 3071, 3072, 1 << 20];
    let bins = rt.twc_bin(&degs, [32, 128, 3072]).unwrap();
    assert_eq!(bins, vec![0, 0, 1, 1, 2, 2, 3, 3]);
    // Against the Rust-side TWC binning for sub-huge degrees.
    use alb_graph::lb::schedule::Unit;
    use alb_graph::lb::twc::bin;
    let spec = GpuSpec::default_sim();
    for (i, &d) in degs.iter().enumerate() {
        if (d as u64) < spec.huge_threshold() {
            let want = match bin(d as u64, &spec) {
                Unit::Thread => 0,
                Unit::Warp => 1,
                Unit::Block => 2,
            };
            assert_eq!(bins[i], want, "degree {d}");
        } else {
            assert_eq!(bins[i], 3, "degree {d} must be huge");
        }
    }
}

#[test]
fn engine_pjrt_equals_native_for_every_app() {
    let Some(rt) = runtime() else { return };
    let el = rmat::generate(&RmatConfig::paper(10, 5));
    let g0 = CsrGraph::from_edge_list(&el);
    let spec = GpuSpec::default_sim();
    let src = g0.max_out_degree_vertex();
    for app in ALL_APPS {
        let mut cfg: EngineConfig = Framework::DIrglAlb.engine_config(spec.clone());
        cfg.compute = ComputeMode::Pjrt;
        let mut g = g0.clone();
        let pjrt_r = run(app, &mut g, src, &cfg, Some(&rt)).unwrap();
        cfg.compute = ComputeMode::Native;
        let mut g = g0.clone();
        let native_r = run(app, &mut g, src, &cfg, None).unwrap();
        if app == App::Pr {
            for (a, b) in pjrt_r.labels.iter().zip(&native_r.labels) {
                assert!((a - b).abs() < 1e-5, "pr {a} vs {b}");
            }
        } else {
            assert_eq!(pjrt_r.labels, native_r.labels, "app {}", app.name());
        }
    }
}

#[test]
fn engine_pjrt_actually_exercises_lb_kernel() {
    let Some(rt) = runtime() else { return };
    let el = rmat::generate(&RmatConfig::paper(11, 6));
    let mut g = CsrGraph::from_edge_list(&el);
    let spec = GpuSpec::default_sim();
    let src = g.max_out_degree_vertex();
    assert!(g.out_degree(src) >= spec.huge_threshold(),
            "input must have a huge vertex for this test");
    let mut cfg: EngineConfig = Framework::DIrglAlb.engine_config(spec);
    cfg.compute = ComputeMode::Pjrt;
    let r = run(App::Bfs, &mut g, src, &cfg, Some(&rt)).unwrap();
    assert!(r.rounds_with_lb() > 0, "LB kernel must have run via PJRT");
}

#[test]
fn distributed_pjrt_smoke() {
    use alb_graph::coordinator::{run_distributed, ClusterConfig};
    let Some(rt) = runtime() else { return };
    let el = rmat::generate(&RmatConfig::paper(9, 8));
    let g = CsrGraph::from_edge_list(&el);
    let src = g.max_out_degree_vertex();
    let mut cfg: EngineConfig =
        Framework::DIrglAlb.engine_config(GpuSpec::default_sim());
    cfg.compute = ComputeMode::Pjrt;
    let r = run_distributed(App::Bfs, &g, src, &cfg,
                            &ClusterConfig::single_host(2), Some(&rt))
        .unwrap();
    let want = alb_graph::apps::bfs::oracle(&g, src);
    assert_eq!(r.labels, want);
}
