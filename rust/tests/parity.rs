//! Parity gates.
//!
//! 1. **Load-balancer parity**: vertex, edge, TWC, and ALB schedules are
//!    *performance* strategies — they must never change answers. BFS and
//!    SSSP labels must be identical across all of them on every bundled
//!    input preset.
//! 2. **Coordinator determinism**: the parallel multi-GPU coordinator must
//!    be bit-identical to the single-threaded sequential reference — same
//!    labels, same modeled cycles, same per-round records — while actually
//!    using multiple OS threads.
//! 3. **Scratch-reuse golden parity**: the zero-allocation hot path
//!    (`RoundScratch` arenas, bitmap frontier, pooled simulator buffers)
//!    must be bit-identical — labels, per-round records, total cycles, and
//!    `DistRunResult` — to the freshly-allocated reference
//!    (`run_push_reference` / `Simulator::simulate_reference`) on every
//!    input preset and balancer.
//! 4. **Parallel-simulation determinism (DESIGN.md §9)**: the intra-GPU
//!    worker-pool simulation must be bit-identical — labels, cycles,
//!    per-round records, and `DistRunResult` — across
//!    `sim_threads ∈ {1, 2, 4, 7}` on every input preset and balancer.
//! 5. **Reordering parity (DESIGN.md §13)**: running on a `--reorder`ed
//!    graph and mapping the labels back through the permutation must be
//!    bit-identical to the unreordered run for the order-invariant apps
//!    (bfs, sssp), on every input preset and balancer.

use alb_graph::apps::engine::{run, run_push_reference, EngineConfig};
use alb_graph::apps::App;
use alb_graph::coordinator::{
    run_distributed, run_distributed_reference, ClusterConfig, ExecMode,
};
use alb_graph::graph::inputs;
use alb_graph::graph::reorder::{self, Reorder};
use alb_graph::lb::{Balancer, Distribution};
use alb_graph::partition::Policy;

const DELTA: i32 = -4; // small but non-trivial inputs for CI

fn parity_balancers() -> Vec<Balancer> {
    vec![
        Balancer::Vertex,
        Balancer::EdgeLb { distribution: Distribution::Cyclic },
        Balancer::Twc,
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
    ]
}

/// Every strategy, including the ones parity_balancers leaves out (blocked
/// distributions, Enterprise) — the scratch-reuse gate must hold for all.
fn all_balancers() -> Vec<Balancer> {
    vec![
        Balancer::Vertex,
        Balancer::Twc,
        Balancer::EdgeLb { distribution: Distribution::Cyclic },
        Balancer::EdgeLb { distribution: Distribution::Blocked },
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
        Balancer::Alb { distribution: Distribution::Blocked, threshold: None },
        Balancer::Enterprise,
    ]
}

#[test]
fn vertex_edge_twc_alb_agree_on_every_input() {
    for input in inputs::ALL_INPUTS {
        let g0 = inputs::build(input, DELTA, 13).unwrap();
        let src = inputs::source_vertex(input, &g0);
        for app in [App::Bfs, App::Sssp] {
            let mut reference: Option<Vec<f32>> = None;
            for balancer in parity_balancers() {
                let name = balancer.name();
                let cfg = EngineConfig {
                    balancer,
                    max_rounds: 1_000_000,
                    ..EngineConfig::default()
                };
                let r = run(app, &mut g0.clone(), src, &cfg, None).unwrap();
                if reference.is_none() {
                    reference = Some(r.labels);
                } else {
                    let want = reference.as_ref().unwrap();
                    assert_eq!(
                        &r.labels, want,
                        "{} labels diverge under {name} on {input}",
                        app.name()
                    );
                }
            }
        }
    }
}

#[test]
fn parallel_coordinator_bit_identical_to_sequential_reference() {
    let input = "rmat18";
    let g = inputs::build(input, DELTA, 17).unwrap();
    let src = inputs::source_vertex(input, &g);
    for app in [App::Bfs, App::Sssp, App::Cc, App::Pr, App::Kcore] {
        let cfg = EngineConfig {
            max_rounds: if app == App::Pr { 100 } else { 1_000_000 },
            ..EngineConfig::default()
        };
        for k in [2u32, 4] {
            let par = run_distributed(
                app,
                &g,
                src,
                &cfg,
                &ClusterConfig::single_host(k),
                None,
            )
            .unwrap();
            let seq = run_distributed(
                app,
                &g,
                src,
                &cfg,
                &ClusterConfig::single_host(k).with_exec(ExecMode::Sequential),
                None,
            )
            .unwrap();
            // Bit-exact labels — even pagerank's f32 sums, because the
            // parallel reduce folds partials in partition order.
            assert_eq!(par.labels, seq.labels, "{} k={k} labels", app.name());
            assert_eq!(
                par.total_cycles,
                seq.total_cycles,
                "{} k={k} cycles",
                app.name()
            );
            assert_eq!(par.rounds, seq.rounds, "{} k={k} round records", app.name());
            assert_eq!(par.per_gpu_comp, seq.per_gpu_comp, "{} k={k}", app.name());
        }
    }
}

#[test]
fn scratch_reuse_bit_identical_to_fresh_alloc_reference() {
    // The golden gate for the zero-allocation refactor: on every bundled
    // input preset and every balancer, the scratch-reuse engine must equal
    // the freshly-allocated reference bit-for-bit — labels, every
    // per-round record (active/edges/cycles/lb_triggered), and the total.
    for input in inputs::ALL_INPUTS {
        let g0 = inputs::build(input, DELTA, 23).unwrap();
        let src = inputs::source_vertex(input, &g0);
        for app in [App::Bfs, App::Sssp] {
            for balancer in all_balancers() {
                let name = balancer.name();
                let cfg = EngineConfig {
                    balancer,
                    max_rounds: 1_000_000,
                    ..EngineConfig::default()
                };
                let hot = run(app, &mut g0.clone(), src, &cfg, None).unwrap();
                let golden =
                    run_push_reference(app, &mut g0.clone(), src, &cfg).unwrap();
                assert_eq!(
                    hot, golden,
                    "{} under {name} on {input} diverges from the \
                     fresh-allocation reference",
                    app.name()
                );
            }
        }
    }
}

#[test]
fn reordered_runs_produce_bit_identical_original_id_labels() {
    // ISSUE 7 acceptance gate: reordering is a *layout* change, never an
    // answer change. For the vertex-order-invariant apps, run the renamed
    // graph from the forward-mapped source, map the labels back through
    // the inverse permutation, and require the exact bits of the
    // unreordered run — same round count too (level sets are sets).
    // cc (min-id labels) and pr (f32 summation order) are excluded by
    // design; DESIGN.md §13 has the legality table.
    let mut back = Vec::new();
    for input in inputs::ALL_INPUTS {
        let g0 = inputs::build(input, DELTA, 31).unwrap();
        let src = inputs::source_vertex(input, &g0);
        for app in [App::Bfs, App::Sssp] {
            for balancer in all_balancers() {
                let name = balancer.name();
                let cfg = EngineConfig {
                    balancer,
                    max_rounds: 1_000_000,
                    ..EngineConfig::default()
                };
                let base = run(app, &mut g0.clone(), src, &cfg, None).unwrap();
                for kind in [Reorder::Degree, Reorder::Rcm] {
                    let (rg, perm) = reorder::reorder(&g0, kind);
                    let r = run(app, &mut rg.clone(), perm.to_new(src), &cfg, None)
                        .unwrap();
                    perm.labels_to_original(&r.labels, &mut back);
                    let ctx = format!(
                        "{} under {name} on {input} reorder={}",
                        app.name(),
                        kind.name()
                    );
                    let bits =
                        |l: &[f32]| l.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(bits(&back), bits(&base.labels), "{ctx}: labels");
                    assert_eq!(
                        r.rounds.len(),
                        base.rounds.len(),
                        "{ctx}: round count"
                    );
                }
            }
        }
    }
}

#[test]
fn per_gpu_scratch_arenas_keep_dist_runs_bit_identical() {
    // DistRunResult leg of the golden gate: per-GPU arenas living across
    // rounds on parallel BSP threads must reproduce the sequential
    // reference exactly, for every balancer (not just the default).
    let input = "rmat18";
    let g = inputs::build(input, DELTA, 29).unwrap();
    let src = inputs::source_vertex(input, &g);
    for balancer in all_balancers() {
        let name = balancer.name();
        let cfg = EngineConfig {
            balancer,
            max_rounds: 1_000_000,
            ..EngineConfig::default()
        };
        let par = run_distributed(
            App::Sssp, &g, src, &cfg, &ClusterConfig::single_host(3), None,
        )
        .unwrap();
        let seq = run_distributed(
            App::Sssp,
            &g,
            src,
            &cfg,
            &ClusterConfig::single_host(3).with_exec(ExecMode::Sequential),
            None,
        )
        .unwrap();
        assert_eq!(par.labels, seq.labels, "{name} labels");
        assert_eq!(par.total_cycles, seq.total_cycles, "{name} cycles");
        assert_eq!(par.rounds, seq.rounds, "{name} rounds");
        assert_eq!(par.per_gpu_comp, seq.per_gpu_comp, "{name} per-gpu");
    }
}

#[test]
fn parallel_coordinator_actually_uses_threads() {
    let g = inputs::build("rmat18", DELTA, 19).unwrap();
    let src = inputs::source_vertex("rmat18", &g);
    // Pin the pool width: the env-driven default may be 1 on the CI leg
    // that exercises the sequential reference (ALB_SIM_THREADS=1).
    let cfg = EngineConfig {
        max_rounds: 1_000_000,
        sim_threads: 4,
        ..EngineConfig::default()
    };
    let par = run_distributed(
        App::Bfs,
        &g,
        src,
        &cfg,
        &ClusterConfig::single_host(4),
        None,
    )
    .unwrap();
    assert!(
        par.num_threads() >= 2,
        "parallel mode must fan out to >= 2 OS threads, saw {}",
        par.num_threads()
    );
    let seq = run_distributed(
        App::Bfs,
        &g,
        src,
        &cfg,
        &ClusterConfig::single_host(4).with_exec(ExecMode::Sequential),
        None,
    )
    .unwrap();
    assert_eq!(seq.num_threads(), 1, "sequential reference must stay inline");
}

/// ISSUE 4 acceptance gate: the rebuilt exchange (precomputed mirror
/// schedules + updated-only bitmask) must reproduce the preserved
/// pre-rebuild coordinator — central master array + per-round g2l HashMap
/// reconciliation — across `ALL_INPUTS` × {oec, iec, cvc} × all five apps:
/// bit-identical labels everywhere; for the push apps the per-round records
/// (compute cycles, comm cycles, byte counts) are identical too; and no
/// round ever exchanges more bytes than the old reconciliation did.
#[test]
fn exchange_bit_identical_to_pre_rebuild_coordinator() {
    for input in inputs::ALL_INPUTS {
        let g = inputs::build(input, DELTA, 43).unwrap();
        let src = inputs::source_vertex(input, &g);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            for app in [App::Bfs, App::Sssp, App::Cc, App::Pr, App::Kcore] {
                let cfg = EngineConfig {
                    max_rounds: if app == App::Pr { 50 } else { 1_000_000 },
                    ..EngineConfig::default()
                };
                let cluster = ClusterConfig {
                    policy,
                    ..ClusterConfig::single_host(4)
                };
                let ctx = format!("{input} {} {policy:?}", app.name());
                let new =
                    run_distributed(app, &g, src, &cfg, &cluster, None)
                        .unwrap();
                let old = run_distributed_reference(
                    app, &g, src, &cfg, &cluster,
                )
                .unwrap();
                assert_eq!(new.labels, old.labels, "{ctx}: labels");
                assert_eq!(
                    new.rounds.len(),
                    old.rounds.len(),
                    "{ctx}: round count"
                );
                for (a, b) in new.rounds.iter().zip(&old.rounds) {
                    assert_eq!(a.active, b.active, "{ctx}: active");
                    assert_eq!(
                        a.comp_cycles, b.comp_cycles,
                        "{ctx}: comp cycles"
                    );
                    assert!(
                        a.comm_bytes <= b.comm_bytes,
                        "{ctx} round {}: exchanged {} bytes > the old \
                         reconciliation's {}",
                        a.round,
                        a.comm_bytes,
                        b.comm_bytes
                    );
                }
                if matches!(app, App::Bfs | App::Sssp | App::Cc) {
                    // The min-reduce apps flow through the schedules with
                    // exactly the old volumes and pairings.
                    assert_eq!(new.rounds, old.rounds, "{ctx}: rounds");
                    assert_eq!(
                        new.total_cycles, old.total_cycles,
                        "{ctx}: total cycles"
                    );
                }
            }
        }
    }
}

/// Exchange-schedule parity, pooled vs sequential, for every policy and
/// app: the plan-driven sync must stay bit-identical whichever way the
/// superstep executes its per-GPU tasks.
#[test]
fn exchange_parallel_bit_identical_to_sequential_every_policy() {
    let g = inputs::build("rmat18", DELTA, 47).unwrap();
    let src = inputs::source_vertex("rmat18", &g);
    for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
        for app in [App::Bfs, App::Sssp, App::Cc, App::Pr, App::Kcore] {
            let cfg = EngineConfig {
                max_rounds: if app == App::Pr { 50 } else { 1_000_000 },
                ..EngineConfig::default()
            };
            let cluster = ClusterConfig {
                policy,
                ..ClusterConfig::single_host(3)
            };
            let par =
                run_distributed(app, &g, src, &cfg, &cluster, None).unwrap();
            let seq = run_distributed(
                app,
                &g,
                src,
                &cfg,
                &cluster.clone().with_exec(ExecMode::Sequential),
                None,
            )
            .unwrap();
            let ctx = format!("{} {policy:?}", app.name());
            assert_eq!(par.labels, seq.labels, "{ctx}: labels");
            assert_eq!(par.total_cycles, seq.total_cycles, "{ctx}: cycles");
            assert_eq!(par.rounds, seq.rounds, "{ctx}: rounds");
            assert_eq!(par.per_gpu_comp, seq.per_gpu_comp, "{ctx}: per-gpu");
            assert_eq!(par.comm_bytes, seq.comm_bytes, "{ctx}: bytes");
        }
    }
}

#[test]
fn pooled_simulation_bit_identical_across_sim_threads_on_all_inputs() {
    // §9 acceptance gate, engine leg: labels, per-round records (active /
    // edges / cycles / lb_triggered / kernel stats), and total cycles are
    // bit-identical across pool widths on every bundled input preset and
    // every balancer. sim_threads=1 is the sequential reference walk.
    for input in inputs::ALL_INPUTS {
        let g0 = inputs::build(input, DELTA, 37).unwrap();
        let src = inputs::source_vertex(input, &g0);
        for balancer in all_balancers() {
            let name = balancer.name();
            let base_cfg = EngineConfig {
                balancer,
                max_rounds: 1_000_000,
                sim_threads: 1,
                ..EngineConfig::default()
            };
            let base = run(App::Bfs, &mut g0.clone(), src, &base_cfg, None).unwrap();
            for threads in [2usize, 4, 7] {
                let cfg = EngineConfig { sim_threads: threads, ..base_cfg.clone() };
                let r = run(App::Bfs, &mut g0.clone(), src, &cfg, None).unwrap();
                assert_eq!(
                    r, base,
                    "{input} under {name} diverges at sim_threads={threads}"
                );
            }
        }
    }
}

#[test]
fn pooled_simulation_bit_identical_across_sim_threads_distributed() {
    // §9 acceptance gate, DistRunResult leg: one shared pool across all
    // simulated GPUs must reproduce the 1-thread run exactly — labels,
    // total/comp/comm cycles, per-round records, per-GPU compute.
    let input = "rmat18";
    let g = inputs::build(input, DELTA, 41).unwrap();
    let src = inputs::source_vertex(input, &g);
    for balancer in all_balancers() {
        let name = balancer.name();
        let base_cfg = EngineConfig {
            balancer,
            max_rounds: 1_000_000,
            sim_threads: 1,
            ..EngineConfig::default()
        };
        let base = run_distributed(
            App::Sssp, &g, src, &base_cfg, &ClusterConfig::single_host(3), None,
        )
        .unwrap();
        for threads in [2usize, 4, 7] {
            let cfg = EngineConfig { sim_threads: threads, ..base_cfg.clone() };
            let r = run_distributed(
                App::Sssp, &g, src, &cfg, &ClusterConfig::single_host(3), None,
            )
            .unwrap();
            assert_eq!(r.labels, base.labels, "{name} labels threads={threads}");
            assert_eq!(
                r.total_cycles, base.total_cycles,
                "{name} cycles threads={threads}"
            );
            assert_eq!(r.rounds, base.rounds, "{name} rounds threads={threads}");
            assert_eq!(
                r.per_gpu_comp, base.per_gpu_comp,
                "{name} per-gpu threads={threads}"
            );
        }
    }
}
