//! Systematic `.albc` corruption fuzz (ISSUE 8 satellite c).
//!
//! The on-disk CSR cache must never trust damaged bytes: this test saves a
//! real entry, then (1) truncates it at **every** possible length and
//! (2) flips a bit in **every** byte — header, sizes, offsets, columns,
//! weights, and the checksum trailer — asserting each mutation fails
//! validation, and that `GraphCache::load_or_build` reports the entry as
//! `Corrupt` and silently regenerates a valid one.

use std::fs;
use std::path::{Path, PathBuf};

use alb_graph::graph::disk::{self, CacheOutcome, GraphCache};
use alb_graph::graph::inputs;

/// Unique temp dir that cleans itself up on drop.
struct TmpDir(PathBuf);
impl TmpDir {
    fn new(tag: &str) -> Self {
        let p = std::env::temp_dir().join(format!(
            "albc-fuzz-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        TmpDir(p)
    }
    fn path(&self) -> &Path {
        &self.0
    }
}
impl Drop for TmpDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

// A tiny but real preset entry: a few hundred vertices keeps the
// every-byte sweep (2 x file-size loads) CI-friendly while exercising all
// sections, staging-buffer chunking included.
const INPUT: &str = "rmat18";
const DELTA: i32 = -10;
const SEED: u64 = 3;

fn pristine(dir: &Path) -> (PathBuf, Vec<u8>) {
    let g = inputs::build(INPUT, DELTA, SEED).unwrap();
    let path = dir.join("fuzz.albc");
    disk::save(&g, &path).unwrap();
    let bytes = fs::read(&path).unwrap();
    assert!(disk::load(&path).is_ok(), "pristine entry must load");
    // Sanity on the layout the fuzz below walks: 28-byte header (magic,
    // version, flags, n, m), offsets + cols + weights payload, u64 trailer.
    let n = (g.row_offsets.len() - 1) as usize;
    let m = g.col_idx.len();
    assert_eq!(bytes.len(), 28 + (n + 1) * 8 + m * 8 + 8);
    (path, bytes)
}

#[test]
fn every_truncation_fails_validation() {
    let tmp = TmpDir::new("trunc");
    let (path, bytes) = pristine(tmp.path());
    for len in 0..bytes.len() {
        fs::write(&path, &bytes[..len]).unwrap();
        assert!(
            disk::load(&path).is_err(),
            "truncation to {len}/{} bytes must fail validation",
            bytes.len()
        );
    }
}

#[test]
fn every_byte_flip_fails_validation() {
    let tmp = TmpDir::new("flip");
    let (path, bytes) = pristine(tmp.path());
    for i in 0..bytes.len() {
        let mut mutated = bytes.clone();
        mutated[i] ^= 0x40;
        fs::write(&path, &mutated).unwrap();
        assert!(
            disk::load(&path).is_err(),
            "bit flip at byte {i}/{} must fail validation",
            bytes.len()
        );
    }
    // The pristine bytes still load — the loop above really was testing
    // the mutations, not a broken fixture.
    fs::write(&path, &bytes).unwrap();
    assert!(disk::load(&path).is_ok());
}

#[test]
fn cache_reports_corrupt_and_regenerates() {
    let tmp = TmpDir::new("regen");
    let cache = GraphCache::new(tmp.path()).unwrap();
    let (g0, o) = cache.load_or_build(INPUT, DELTA, SEED).unwrap();
    assert_eq!(o, CacheOutcome::Miss);
    let entry = cache.entry_path(INPUT, DELTA, SEED);

    // Corrupt a mid-payload byte: the next load_or_build must say so,
    // rebuild the same graph, and leave a valid entry behind.
    let mut bytes = fs::read(&entry).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    fs::write(&entry, &bytes).unwrap();
    let (g1, o1) = cache.load_or_build(INPUT, DELTA, SEED).unwrap();
    assert_eq!(o1, CacheOutcome::Corrupt);
    assert_eq!(g0.row_offsets, g1.row_offsets);
    assert_eq!(g0.col_idx, g1.col_idx);

    let (_, o2) = cache.load_or_build(INPUT, DELTA, SEED).unwrap();
    assert_eq!(o2, CacheOutcome::Hit, "regenerated entry must be valid");
}
