//! Cross-module integration: inputs -> partitioner -> engines -> oracles,
//! plus CLI smoke tests against the built binary.

use std::process::Command;

use alb_graph::apps::engine::{run, EngineConfig};
use alb_graph::apps::{bfs, cc, kcore, pr, sssp, App};
use alb_graph::config::Framework;
use alb_graph::coordinator::{run_distributed, ClusterConfig};
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::inputs;

const DELTA: i32 = -4; // small but non-trivial inputs for CI

#[test]
fn every_app_matches_oracle_on_every_input() {
    for input in inputs::ALL_INPUTS {
        let g0 = inputs::build(input, DELTA, 3).unwrap();
        let src = inputs::source_vertex(input, &g0);
        let cfg = EngineConfig { max_rounds: 1_000_000, ..EngineConfig::default() };

        let r = run(App::Bfs, &mut g0.clone(), src, &cfg, None).unwrap();
        assert_eq!(r.labels, bfs::oracle(&g0, src), "bfs {input}");

        let r = run(App::Sssp, &mut g0.clone(), src, &cfg, None).unwrap();
        assert_eq!(r.labels, sssp::oracle(&g0, src), "sssp {input}");

        let r = run(App::Cc, &mut g0.clone(), src, &cfg, None).unwrap();
        assert_eq!(r.labels, cc::oracle(&g0), "cc {input}");

        let r = run(App::Kcore, &mut g0.clone(), src, &cfg, None).unwrap();
        let (want, _) = kcore::oracle(&mut g0.clone(), cfg.kcore_k);
        let got: Vec<bool> = r.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want, "kcore {input}");

        let prcfg = EngineConfig { max_rounds: 100, ..cfg.clone() };
        let r = run(App::Pr, &mut g0.clone(), src, &prcfg, None).unwrap();
        let (want, _) = pr::oracle(&mut g0.clone(), prcfg.pr_tol, 100);
        assert_eq!(r.labels, want, "pr {input}");
    }
}

#[test]
fn frameworks_agree_on_answers_not_on_time() {
    let g0 = inputs::build("rmat18", DELTA, 9).unwrap();
    let src = inputs::source_vertex("rmat18", &g0);
    let spec = GpuSpec::default_sim();
    let mut labels: Vec<Vec<f32>> = Vec::new();
    let mut cycles: Vec<u64> = Vec::new();
    for fw in [
        Framework::DIrglTwc,
        Framework::DIrglAlb,
        Framework::GunrockTwc,
        Framework::GunrockLb,
        Framework::Lux,
    ] {
        let cfg = fw.engine_config(spec.clone());
        let r = run(App::Bfs, &mut g0.clone(), src, &cfg, None).unwrap();
        labels.push(r.labels);
        cycles.push(r.total_cycles);
    }
    for l in &labels[1..] {
        assert_eq!(*l, labels[0]);
    }
    // Timing must differ between at least some frameworks (they are
    // different strategies, not aliases).
    assert!(cycles.iter().any(|&c| c != cycles[0]));
}

#[test]
fn distributed_agrees_with_single_for_all_apps() {
    let g = inputs::build("rmat18", DELTA, 11).unwrap();
    let src = inputs::source_vertex("rmat18", &g);
    let cfg = EngineConfig { max_rounds: 1_000_000, ..EngineConfig::default() };
    for app in [App::Bfs, App::Sssp, App::Cc, App::Kcore] {
        let single = run(app, &mut g.clone(), src, &cfg, None).unwrap();
        for k in [2u32, 3, 6] {
            let dist = run_distributed(app, &g, src, &cfg,
                                       &ClusterConfig::single_host(k), None)
                .unwrap();
            assert_eq!(dist.labels, single.labels, "{} k={k}", app.name());
        }
    }
    // pr with fp tolerance.
    let prcfg = EngineConfig { max_rounds: 100, ..cfg };
    let single = run(App::Pr, &mut g.clone(), src, &prcfg, None).unwrap();
    let dist = run_distributed(App::Pr, &g, src, &prcfg,
                               &ClusterConfig::single_host(4), None)
        .unwrap();
    for (a, b) in dist.labels.iter().zip(&single.labels) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn alb_end_to_end_speedup_on_paper_regime() {
    // The headline claim at integration level, default-scale rmat.
    let g = inputs::build("rmat18", 0, 42).unwrap();
    let src = inputs::source_vertex("rmat18", &g);
    let spec = GpuSpec::default_sim();
    let twc = run(App::Bfs, &mut g.clone(), src,
                  &Framework::DIrglTwc.engine_config(spec.clone()), None)
        .unwrap();
    let alb = run(App::Bfs, &mut g.clone(), src,
                  &Framework::DIrglAlb.engine_config(spec.clone()), None)
        .unwrap();
    let speedup = twc.total_cycles as f64 / alb.total_cycles as f64;
    assert!(speedup > 1.5, "expected paper-shaped speedup, got {speedup:.2}x");
    // And dormancy on the road input.
    let g = inputs::build("road-s", DELTA, 42).unwrap();
    let alb_road = run(App::Bfs, &mut g.clone(), 0,
                       &Framework::DIrglAlb.engine_config(spec.clone()), None)
        .unwrap();
    let twc_road = run(App::Bfs, &mut g.clone(), 0,
                       &Framework::DIrglTwc.engine_config(spec), None)
        .unwrap();
    assert_eq!(alb_road.rounds_with_lb(), 0);
    assert_eq!(alb_road.total_cycles, twc_road.total_cycles);
}

// ------------------------------------------------------------- CLI smoke

fn alb_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_alb"))
}

#[test]
fn cli_props_runs() {
    let out = alb_bin()
        .args(["props", "--input", "rmat18", "--scale-delta", "-5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("rmat18"));
    assert!(stdout.contains("maxDout"));
}

#[test]
fn cli_run_single_and_multi() {
    let out = alb_bin()
        .args(["run", "--app", "bfs", "--input", "rmat18", "--scale-delta",
               "-5", "--framework", "dirgl-alb"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = alb_bin()
        .args(["run", "--app", "sssp", "--input", "rmat18", "--scale-delta",
               "-5", "--gpus", "4", "--policy", "oec"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("comp"));
}

#[test]
fn cli_gen_roundtrip_and_json() {
    let dir = std::env::temp_dir();
    let graph_path = dir.join(format!("alb-cli-{}.albg", std::process::id()));
    let json_path = dir.join(format!("alb-cli-{}.json", std::process::id()));
    let out = alb_bin()
        .args(["gen", "--input", "road-s", "--scale-delta", "-5", "--out",
               graph_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let out = alb_bin()
        .args(["run", "--app", "bfs", "--input", graph_path.to_str().unwrap(),
               "--json", json_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let js = std::fs::read_to_string(&json_path).unwrap();
    assert!(js.contains("\"simulated_ms\""));
    let _ = std::fs::remove_file(graph_path);
    let _ = std::fs::remove_file(json_path);
}

#[test]
fn cli_rejects_unknown_args() {
    assert!(!alb_bin().args(["run", "--app", "nope", "--input", "rmat18"])
        .output().unwrap().status.success());
    assert!(!alb_bin().args(["frobnicate"]).output().unwrap().status.success());
    assert!(!alb_bin().args(["repro", "fig99"]).output().unwrap().status.success());
}
