//! Tier-1 gate for `alb lint` (DESIGN.md §15).
//!
//! Three layers:
//!
//! 1. the real tree must lint clean, with every suppression justified and
//!    no stale allowlist entries;
//! 2. a bad-snippet fixture corpus proves each rule ID fires exactly once
//!    on its fixture and stays silent on the matching clean variant;
//! 3. mutation tests on *real* files prove the gate is armed: stripping a
//!    single `SAFETY:` comment or renaming a single `*_ref` twin makes
//!    this test binary — and therefore tier-1 — fail.

use std::fs;
use std::path::PathBuf;

use alb_graph::analysis::rules;
use alb_graph::analysis::{self, allowlist, lint_source, Diagnostic, SourceFile, Tree};

fn root() -> PathBuf {
    // Cargo.toml lives at the repository root, so the manifest dir is the
    // lint root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Run the file-scoped rules and flatten to comparable (rule, line) pairs.
fn fired(path: &str, src: &str) -> Vec<(String, usize)> {
    lint_source(path, src)
        .into_iter()
        .map(|d| (d.rule.to_string(), d.line))
        .collect()
}

fn pairs(diags: &[Diagnostic]) -> Vec<(String, usize)> {
    diags.iter().map(|d| (d.rule.to_string(), d.line)).collect()
}

fn mini_tree(files: &[(&str, &str)], design: &str, manifest: &str) -> Tree {
    Tree {
        files: files.iter().map(|(p, s)| SourceFile::new(*p, *s)).collect(),
        design_sections: rules::design_sections(design),
        manifest: manifest.to_string(),
    }
}

// ------------------------------------------------------------ real tree

/// The headline invariant: `alb lint` passes on this repository.
#[test]
fn real_tree_is_lint_clean() {
    let report = analysis::run_lint(&root()).expect("lint walk failed");
    if !report.clean() {
        for d in &report.diagnostics {
            eprintln!("{}", d.render());
        }
        for s in &report.stale {
            eprintln!("{s}");
        }
    }
    assert!(report.clean(), "alb lint found violations (see stderr)");
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
}

/// The raw (pre-allowlist) diagnostics are exactly the six documented
/// suppressions: one D002 in campaign/runner.rs and five U002 in
/// rust/tests/alloc.rs. Anything else is a new violation; anything fewer
/// means an allowlist entry just went stale.
#[test]
fn real_tree_raw_diagnostics_match_the_allowlist() {
    let tree = analysis::load_tree(&root()).expect("load tree");
    let diags = rules::lint_tree(&tree);
    let d002: Vec<_> = diags.iter().filter(|d| d.rule == "D002").collect();
    let u002: Vec<_> = diags.iter().filter(|d| d.rule == "U002").collect();
    assert_eq!(d002.len(), 1, "D002 sites drifted: {:?}", pairs(&diags));
    assert_eq!(d002[0].file, "rust/src/campaign/runner.rs");
    assert_eq!(u002.len(), 5, "U002 sites drifted: {:?}", pairs(&diags));
    assert!(u002.iter().all(|d| d.file == "rust/tests/alloc.rs"));
    assert_eq!(diags.len(), 6, "unexpected raw diagnostics: {:?}", pairs(&diags));

    let report = analysis::run_lint(&root()).expect("lint walk failed");
    assert_eq!(report.suppressed, 6);
}

/// Every committed allowlist entry parses and carries a justification.
#[test]
fn committed_allowlist_is_well_formed_and_justified() {
    let text = fs::read_to_string(root().join(analysis::ALLOWLIST_FILE)).unwrap();
    let list = allowlist::parse(&text);
    assert!(list.errors.is_empty(), "allowlist errors: {:?}", list.errors);
    assert_eq!(list.entries.len(), 2);
    assert!(list.entries.iter().all(|e| !e.why.is_empty()));
}

/// If the code an entry covers disappears, the entry goes stale and the
/// run fails — the allowlist cannot silently outlive the tree.
#[test]
fn stale_allowlist_entries_fail_the_run() {
    let text = fs::read_to_string(root().join(analysis::ALLOWLIST_FILE)).unwrap();
    let applied = allowlist::parse(&text).apply(Vec::new());
    assert_eq!(applied.stale.len(), 2, "stale detection is not armed");
    assert_eq!(applied.suppressed, 0);
}

/// The committed twin manifest parses cleanly and covers the five SWAR
/// hot paths.
#[test]
fn committed_twin_manifest_is_well_formed() {
    let (entries, diags) = rules::parse_manifest(analysis::TWINS_MANIFEST);
    assert!(diags.is_empty(), "{:?}", pairs(&diags));
    assert_eq!(entries.len(), 5);
    assert!(entries.iter().all(|e| e.twin.ends_with("_ref")));
}

// ----------------------------------------------- armed-gate mutation tests

/// Stripping one `SAFETY:` comment from the real exec pool makes U001
/// fire — the acceptance criterion that tier-1 notices a lost safety
/// argument.
#[test]
fn removing_a_safety_comment_from_exec_fails_lint() {
    let path = "rust/src/exec/mod.rs";
    let src = fs::read_to_string(root().join(path)).unwrap();
    assert!(src.contains("SAFETY:"), "exec/mod.rs lost its safety comments");
    assert!(
        fired(path, &src).is_empty(),
        "exec/mod.rs no longer lints clean as-is"
    );
    let broken = src.replacen("SAFETY:", "NOTE:", 1);
    let diags = lint_source(path, &broken);
    assert!(
        diags.iter().any(|d| d.rule == "U001"),
        "U001 did not fire after stripping a SAFETY comment: {:?}",
        pairs(&diags)
    );
}

/// Same arming check for the counting-allocator test shim: its five
/// suppressed U002 sites still demand SAFETY comments (U001 applies).
#[test]
fn removing_a_safety_comment_from_alloc_shim_fails_lint() {
    let path = "rust/tests/alloc.rs";
    let src = fs::read_to_string(root().join(path)).unwrap();
    let before = lint_source(path, &src);
    assert!(
        before.iter().all(|d| d.rule == "U002"),
        "alloc.rs should only carry allowlisted U002: {:?}",
        pairs(&before)
    );
    let broken = src.replacen("SAFETY:", "NOTE:", 1);
    let diags = lint_source(path, &broken);
    assert!(
        diags.iter().any(|d| d.rule == "U001"),
        "U001 did not fire after stripping a SAFETY comment: {:?}",
        pairs(&diags)
    );
}

/// Renaming a `*_ref` twin in the loaded tree makes T001 fire — the
/// acceptance criterion that tier-1 notices a lost scalar twin.
#[test]
fn removing_a_ref_twin_fails_lint() {
    let mut tree = analysis::load_tree(&root()).expect("load tree");
    let path = "rust/src/apps/worklist.rs";
    let idx = tree
        .files
        .iter()
        .position(|f| f.path == path)
        .expect("worklist.rs missing from tree");
    let src = fs::read_to_string(root().join(path)).unwrap();
    let renamed = src.replace("take_sorted_into_ref", "take_sorted_into_gone");
    assert_ne!(src, renamed, "twin name not found in worklist.rs");
    tree.files[idx] = SourceFile::new(path, &renamed);
    let diags = rules::lint_tree(&tree);
    assert!(
        diags
            .iter()
            .any(|d| d.rule == "T001" && d.file == path),
        "T001 did not fire after renaming a twin: {:?}",
        pairs(&diags)
    );
}

// ------------------------------------------------------- fixture corpus
//
// Each bad fixture fires its rule exactly once; each clean variant is
// silent. Paths are synthetic — `lint_source` never touches the disk.

#[test]
fn d001_fires_once_on_wall_clock_in_result_code() {
    let src = "use std::time::Instant;\n\
               \n\
               pub fn probe() -> u128 {\n\
               \x20   let t0 = Instant::now();\n\
               \x20   t0.elapsed().as_nanos()\n\
               }\n";
    assert_eq!(fired("rust/src/apps/probe.rs", src), vec![("D001".into(), 4)]);
    // The same code is fine at the allowlisted host-timing sites...
    assert!(fired("rust/src/metrics/bench.rs", src).is_empty());
    assert!(fired("rust/src/coordinator/elastic.rs", src).is_empty());
    // ...outside rust/src/ ...
    assert!(fired("rust/tests/probe.rs", src).is_empty());
    // ...and inside a #[cfg(test)] region.
    let in_tests = format!("#[cfg(test)]\nmod tests {{\n{src}}}\n");
    assert!(fired("rust/src/apps/probe.rs", &in_tests).is_empty());
}

#[test]
fn d001_fires_once_on_system_time() {
    let src = "pub fn stamp() -> u64 {\n\
               \x20   let _t = std::time::SystemTime::now();\n\
               \x20   0\n\
               }\n";
    assert_eq!(fired("rust/src/gpu/stamp.rs", src), vec![("D001".into(), 2)]);
}

#[test]
fn d002_fires_once_on_for_loop_over_hash_map() {
    let src = "use std::collections::HashMap;\n\
               pub fn tally(xs: &[(String, u32)]) -> u32 {\n\
               \x20   let mut m = HashMap::new();\n\
               \x20   for (k, v) in xs { m.insert(k.clone(), *v); }\n\
               \x20   let mut sum = 0;\n\
               \x20   for (_k, v) in &m {\n\
               \x20       sum += v;\n\
               \x20   }\n\
               \x20   sum\n\
               }\n";
    assert_eq!(fired("rust/src/apps/tally.rs", src), vec![("D002".into(), 6)]);
}

#[test]
fn d002_fires_once_on_multiline_method_chain() {
    // Mirrors the campaign/runner.rs shape the allowlist covers: the
    // receiver sits on the line before the hash-ordered method call.
    let src = "use std::collections::HashMap;\n\
               pub fn drain(prior: HashMap<String, u32>) -> Vec<(String, u32)> {\n\
               \x20   let mut keep: Vec<(String, u32)> = prior\n\
               \x20       .into_iter()\n\
               \x20       .collect();\n\
               \x20   keep.sort();\n\
               \x20   keep\n\
               }\n";
    assert_eq!(fired("rust/src/apps/drain.rs", src), vec![("D002".into(), 4)]);
}

#[test]
fn d002_is_silent_on_btree_iteration_and_hash_lookups() {
    let src = "use std::collections::{BTreeMap, HashMap};\n\
               pub fn ok(m: &BTreeMap<String, u32>, h: &HashMap<String, u32>) -> u32 {\n\
               \x20   let mut s = 0;\n\
               \x20   for (_k, v) in m {\n\
               \x20       s += v;\n\
               \x20   }\n\
               \x20   s + h.get(\"x\").copied().unwrap_or(0)\n\
               }\n";
    assert!(fired("rust/src/apps/ok.rs", src).is_empty());
}

#[test]
fn d003_fires_once_on_random_state() {
    let src = "pub fn hasher_state() -> u64 {\n\
               \x20   let s = std::collections::hash_map::RandomState::new();\n\
               \x20   let _ = s;\n\
               \x20   0\n\
               }\n";
    assert_eq!(fired("rust/src/lb/seed.rs", src), vec![("D003".into(), 2)]);
    // Test-region and non-src uses stay legal.
    let in_tests = format!("#[cfg(test)]\nmod tests {{\n{src}}}\n");
    assert!(fired("rust/src/lb/seed.rs", &in_tests).is_empty());
    assert!(fired("rust/tests/seed.rs", src).is_empty());
}

#[test]
fn d003_fires_once_on_rand_crate_paths() {
    let src = "pub fn roll() -> u32 {\n\
               \x20   rand::random()\n\
               }\n";
    assert_eq!(fired("rust/src/gpu/roll.rs", src), vec![("D003".into(), 2)]);
}

#[test]
fn u001_fires_once_without_a_safety_comment() {
    // comm/bsp.rs is U002-exempt, so only the missing comment fires.
    let src = "pub fn read_raw(p: *const u32) -> u32 {\n\
               \x20   unsafe { *p }\n\
               }\n";
    assert_eq!(fired("rust/src/comm/bsp.rs", src), vec![("U001".into(), 2)]);
}

#[test]
fn u001_accepts_same_line_and_preceding_block_comments() {
    let same_line = "pub fn read_raw(p: *const u32) -> u32 {\n\
                     \x20   unsafe { *p } // SAFETY: caller guarantees p is valid\n\
                     }\n";
    assert!(fired("rust/src/comm/bsp.rs", same_line).is_empty());
    let block = "pub fn read_raw(p: *const u32) -> u32 {\n\
                 \x20   // SAFETY: caller guarantees p is valid and aligned\n\
                 \x20   // (checked at both call sites).\n\
                 \x20   unsafe { *p }\n\
                 }\n";
    assert!(fired("rust/src/comm/bsp.rs", block).is_empty());
}

#[test]
fn u001_rejects_a_blank_line_between_comment_and_block() {
    // "Immediately preceding" means contiguous: a blank line breaks the
    // comment block.
    let src = "pub fn read_raw(p: *const u32) -> u32 {\n\
               \x20   // SAFETY: caller guarantees p is valid\n\
               \n\
               \x20   unsafe { *p }\n\
               }\n";
    assert_eq!(fired("rust/src/comm/bsp.rs", src), vec![("U001".into(), 4)]);
}

#[test]
fn u001_is_not_fooled_by_identifiers_or_strings() {
    let src = "pub fn unsafe_count() -> usize {\n\
               \x20   let tag = \"unsafe\";\n\
               \x20   tag.len()\n\
               }\n";
    assert!(fired("rust/src/comm/bsp.rs", src).is_empty());
}

#[test]
fn u002_fires_once_outside_the_audited_modules() {
    // A SAFETY comment is present, so confinement is the only violation.
    let src = "pub fn read_raw(p: *const u32) -> u32 {\n\
               \x20   // SAFETY: caller guarantees p is valid\n\
               \x20   unsafe { *p }\n\
               }\n";
    assert_eq!(fired("rust/src/gpu/sim_x.rs", src), vec![("U002".into(), 3)]);
    assert!(fired("rust/src/exec/mod.rs", src).is_empty());
    assert!(fired("rust/src/comm/bsp.rs", src).is_empty());
}

#[test]
fn c001_fires_once_when_the_valid_set_is_missing() {
    let src = "pub fn parse_mode(v: &str) -> String {\n\
               \x20   format!(\"unknown --mode {v}\")\n\
               }\n";
    assert_eq!(fired("rust/src/config/mode.rs", src), vec![("C001".into(), 2)]);
    // Outside rust/src/ the rule does not apply.
    assert!(fired("rust/tests/mode.rs", src).is_empty());
}

#[test]
fn c001_accepts_messages_that_name_the_valid_set() {
    let listed = "pub fn parse_mode(v: &str) -> String {\n\
                  \x20   format!(\"unknown --mode {v}; valid values: oec, iec, cvc\")\n\
                  }\n";
    assert!(fired("rust/src/config/mode.rs", listed).is_empty());
    let alternation = "pub fn parse_mode(v: &str) -> String {\n\
                       \x20   format!(\"unknown --mode {v}: want oec|iec|cvc\")\n\
                       }\n";
    assert!(fired("rust/src/config/mode.rs", alternation).is_empty());
    let range = "pub fn parse_scale(v: &str) -> String {\n\
                 \x20   format!(\"bad --scale {v}: want 1..=24\")\n\
                 }\n";
    assert!(fired("rust/src/config/mode.rs", range).is_empty());
}

#[test]
fn c001_is_not_satisfied_by_the_word_invalid_alone() {
    let src = "pub fn parse_mode(v: &str) -> String {\n\
               \x20   format!(\"invalid --mode {v}\")\n\
               }\n";
    assert_eq!(fired("rust/src/config/mode.rs", src), vec![("C001".into(), 2)]);
}

#[test]
fn c002_fires_once_on_a_dangling_design_reference() {
    let design = "# design\n\n## §1 One\n\nbody\n\n## §2 Two\n";
    let good = "// Invariants pinned in DESIGN.md \u{a7}2.\npub fn f() {}\n";
    let tree = mini_tree(&[("rust/src/x.rs", good)], design, "");
    assert!(rules::lint_tree(&tree).is_empty());

    let bad = "// Invariants pinned in DESIGN.md \u{a7}2.\n\
               pub fn f() {}\n\
               // Stale pointer: DESIGN.md \u{a7}9.\n";
    let tree = mini_tree(&[("rust/src/x.rs", bad)], design, "");
    let diags = rules::lint_tree(&tree);
    assert_eq!(pairs(&diags), vec![("C002".into(), 3)]);
}

#[test]
fn t_rules_pass_on_a_complete_twin() {
    let src = "pub fn fast(x: u32) -> u32 { x }\n\
               pub fn fast_ref(x: u32) -> u32 { x }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   #[test]\n\
               \x20   fn parity() {\n\
               \x20       assert_eq!(super::fast(3), super::fast_ref(3));\n\
               \x20   }\n\
               }\n";
    let manifest = "hot-path | fast | rust/src/x.rs | fast_ref\n";
    let tree = mini_tree(&[("rust/src/x.rs", src)], "", manifest);
    assert!(rules::lint_tree(&tree).is_empty());
}

#[test]
fn t001_fires_once_when_the_twin_is_missing() {
    let src = "pub fn fast(x: u32) -> u32 { x }\n";
    let manifest = "hot-path | fast | rust/src/x.rs | fast_ref\n";
    let tree = mini_tree(&[("rust/src/x.rs", src)], "", manifest);
    let diags = rules::lint_tree(&tree);
    assert_eq!(pairs(&diags), vec![("T001".into(), 0)]);
}

#[test]
fn t001_fires_when_the_optimized_path_or_file_is_missing() {
    // Optimized fn gone but twin present and referenced.
    let src = "pub fn fast_ref(x: u32) -> u32 { x }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   #[test]\n\
               \x20   fn parity() { assert_eq!(super::fast_ref(3), 3); }\n\
               }\n";
    let manifest = "hot-path | fast | rust/src/x.rs | fast_ref\n";
    let tree = mini_tree(&[("rust/src/x.rs", src)], "", manifest);
    let diags = rules::lint_tree(&tree);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].rule, "T001");

    // Whole file gone from the tree.
    let tree = mini_tree(&[("rust/src/y.rs", "pub fn g() {}\n")], "", manifest);
    let diags = rules::lint_tree(&tree);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].rule, "T001");
    assert_eq!(diags[0].file, "rust/src/x.rs");
}

#[test]
fn t001_fires_on_a_malformed_manifest_line() {
    let manifest = "just-two | fields\n";
    let tree = mini_tree(&[("rust/src/x.rs", "pub fn f() {}\n")], "", manifest);
    let diags = rules::lint_tree(&tree);
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].rule, "T001");
    assert_eq!(diags[0].file, "rust/src/analysis/twins.list");
    assert_eq!(diags[0].line, 1);
}

#[test]
fn t002_fires_once_when_the_twin_is_never_tested() {
    let src = "pub fn fast(x: u32) -> u32 { x }\n\
               pub fn fast_ref(x: u32) -> u32 { x }\n";
    let manifest = "hot-path | fast | rust/src/x.rs | fast_ref\n";
    let tree = mini_tree(&[("rust/src/x.rs", src)], "", manifest);
    let diags = rules::lint_tree(&tree);
    assert_eq!(pairs(&diags), vec![("T002".into(), 2)]);

    // A reference from rust/tests/ satisfies it.
    let parity = "#[test]\nfn parity() { assert_eq!(x::fast(1), x::fast_ref(1)); }\n";
    let tree = mini_tree(
        &[("rust/src/x.rs", src), ("rust/tests/parity.rs", parity)],
        "",
        manifest,
    );
    assert!(rules::lint_tree(&tree).is_empty());
}

// ------------------------------------------------------------- reporting

#[test]
fn json_report_carries_the_diagnostics_and_verdict() {
    let clean = analysis::LintReport {
        diagnostics: Vec::new(),
        suppressed: 3,
        stale: Vec::new(),
        files_scanned: 12,
    };
    let js = clean.to_json().to_string_pretty();
    assert!(js.contains("\"clean\": true"), "{js}");
    assert!(js.contains("\"suppressed\": 3"), "{js}");

    let dirty = analysis::LintReport {
        diagnostics: vec![Diagnostic {
            rule: "D001",
            file: "rust/src/x.rs".into(),
            line: 7,
            message: "wall-clock read".into(),
            text: "let t0 = Instant::now();".into(),
        }],
        suppressed: 0,
        stale: vec!["stale entry".into()],
        files_scanned: 12,
    };
    let js = dirty.to_json().to_string_pretty();
    assert!(js.contains("\"clean\": false"), "{js}");
    assert!(js.contains("\"D001\""), "{js}");
    assert!(js.contains("stale entry"), "{js}");
    let text = dirty.render_text();
    assert!(text.contains("D001 rust/src/x.rs:7"), "{text}");
}
