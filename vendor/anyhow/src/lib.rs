//! A small, dependency-free, offline stand-in for the `anyhow` crate.
//!
//! The workspace builds in environments with no crates.io access, so this
//! path dependency provides exactly the subset of anyhow's API the codebase
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! [`anyhow!`] / [`bail!`] macros. Swapping in the real crate later is a
//! one-line `Cargo.toml` change — no call sites need to move.
//!
//! Differences from upstream anyhow (none of which the codebase relies on):
//! `Error` stores its cause chain as rendered strings rather than boxed
//! `dyn Error` values, and `Display` always prints the full `": "`-joined
//! chain (upstream prints only the outermost message unless `{:#}` is used).

use core::fmt;

/// An error: an outermost message plus a chain of rendered causes.
pub struct Error {
    /// `chain[0]` is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().enumerate() {
            if i > 0 {
                f.write_str(": ")?;
            }
            f.write_str(msg)?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: any std error converts via `?`. `Error` itself
// deliberately does NOT implement `std::error::Error`, which is what keeps
// this blanket impl coherent with `impl<T> From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = core::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for core::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { chain: vec![ctx.to_string(), e.to_string()] })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { chain: vec![f().to_string(), e.to_string()] })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or a printable
/// value — `anyhow!("...")`, `anyhow!("{x} failed: {e:?}", ...)`.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::fmt::format(::core::format_args!($fmt $(, $($arg)*)?)))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_joins_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: core::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config: missing");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
        assert_eq!(Some(7).context("never used").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        let x = 5;
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {x}").to_string(), "x = 5");
        assert_eq!(anyhow!("x = {}, y = {}", x, 6).to_string(), "x = 5, y = 6");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");

        fn bails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(1)
        }
        assert_eq!(bails(false).unwrap(), 1);
        assert_eq!(bails(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("c").context("b").context("a");
        let parts: Vec<&str> = e.chain().collect();
        assert_eq!(parts, vec!["a", "b", "c"]);
    }
}
