//! A small, dependency-free, offline stand-in for the `xla` PJRT bindings
//! crate.
//!
//! The real runtime (`rust/src/runtime/pjrt.rs`) is written against the
//! `xla` bindings crate, which cannot be vendored offline (it builds and
//! links the XLA C++ libraries). This path dependency provides exactly the
//! type and method surface that code uses, so `cargo check --features xla`
//! keeps the real implementation compiling — CI's anti-rot leg — while
//! every entry point that would actually reach PJRT reports unavailability
//! at runtime. Swapping in the real bindings is a one-line `Cargo.toml`
//! change; no call sites move.
//!
//! The client-side types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//! [`PjRtBuffer`]) are *uninhabited*: [`PjRtClient::cpu`] is the only way
//! to obtain one and it always errors here, so code paths past client
//! creation typecheck but are statically unreachable — the same pattern as
//! the `not(feature = "xla")` stub in `rust/src/runtime/mod.rs`.

use std::borrow::Borrow;
use std::fmt;

/// Rendered stand-in for the bindings crate's error enum. Only the
/// `Display`/`Debug` surface is relied on (call sites format with `{e:?}`
/// or attach context via `anyhow`).
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `xla::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the real `xla` bindings crate is not vendored; this is the \
         offline API shim (vendor/xla) that only keeps the PJRT runtime \
         compiling — see DESIGN.md §7"
    ))
}

/// Element types [`Literal::vec1`] / [`Literal::to_vec`] accept — the
/// subset of the bindings crate's native types the runtime uses.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host-side literal (dense array) handle. Constructible — literals are
/// built before any client call — but unreadable offline.
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Copy the literal back to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Destructure a 2-tuple result literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }
}

/// Parsed HLO module (text form, as emitted by an AOT export pipeline).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO *text* (not a serialized proto) from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. Uninhabited offline: [`PjRtClient::cpu`] always
/// errors, so every downstream method is statically unreachable.
pub enum PjRtClient {}

impl PjRtClient {
    /// Create the CPU client. Always errors in the shim.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match *self {}
    }
}

/// A compiled, loaded executable. Uninhabited offline (only
/// [`PjRtClient::compile`] produces one).
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Run the executable; the outer `Vec` is per-device, the inner one
    /// per-output.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// Device-resident result buffer. Uninhabited offline (only
/// [`PjRtLoadedExecutable::execute`] produces one).
pub enum PjRtBuffer {}

impl PjRtBuffer {
    /// Fetch the buffer to a host [`Literal`], blocking.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_shim() {
        let err = PjRtClient::cpu().err().expect("shim client must not load");
        let msg = err.to_string();
        assert!(msg.contains("not vendored"), "{msg}");
        assert!(msg.contains("vendor/xla"), "{msg}");
    }

    #[test]
    fn host_side_surface_is_constructible() {
        // Literals and computations are built before any client call, so
        // they must construct (and clone) without a client.
        let lit = Literal::vec1(&[1i32, 2, 3]);
        let _also = lit.clone();
        assert!(lit.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[0f32]).to_tuple1().is_err());
        assert!(Literal::vec1(&[0f32]).to_tuple2().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
