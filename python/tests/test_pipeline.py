"""L1+L2 pipeline test: a complete LB round — inspect (prefix sum) ->
distribute (cyclic / blocked edge ids) -> relax (vectorized search) ->
min-merge — composed exactly the way the Rust engine drives the compiled
artifacts, validated against a plain numpy evaluation of the same round.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")

H, B, S = 256, 2048, 2048
INF = float(2.0**30)


def _round_inputs(seed):
    rng = np.random.default_rng(seed)
    degs = np.zeros(H, np.int32)
    nhuge = rng.integers(1, 8)
    degs[:nhuge] = rng.integers(100, 250, size=nhuge)
    src_dist = rng.uniform(0.0, 20.0, size=H).astype(np.float32)
    return degs, src_dist, rng


def _numpy_round(degs, src_dist, eids, weights, dst_slot, cur):
    """Straight-line numpy evaluation of one LB round."""
    prefix = np.cumsum(degs)
    src = np.searchsorted(prefix, eids, side="right")
    cand = src_dist[src] + weights
    out = cur.copy()
    for e, c in zip(dst_slot, cand):
        out[e] = min(out[e], c)
    return prefix, out


@given(st.integers(min_value=0, max_value=9999),
       st.sampled_from(["cyclic", "blocked"]))
def test_full_lb_round_matches_numpy(seed, order):
    degs, src_dist, rng = _round_inputs(seed)
    total = int(degs.sum())
    assert 0 < total <= B

    # 1. Inspector: prefix sum through the Pallas scan kernel.
    (prefix,) = model.inspect_prefix(jnp.asarray(degs))
    prefix = np.asarray(prefix)
    np.testing.assert_array_equal(prefix, np.cumsum(degs))

    # 2. Distribution: the schedule order is the host's choice — the kernel
    #    must be order-agnostic. p = a pretend thread count.
    ids = np.arange(total, dtype=np.int32)
    p = 37
    if order == "cyclic":
        ids = np.concatenate([ids[t::p] for t in range(p)])
    else:
        w = -(-total // p)
        ids = np.concatenate([ids[t * w:(t + 1) * w] for t in range(p)])
    eids = np.zeros(B, np.int32)
    eids[:total] = ids
    weights = rng.uniform(0.0, 4.0, size=B).astype(np.float32)
    valid = np.zeros(B, np.int32)
    valid[:total] = 1
    dst_slot = rng.integers(0, S, size=B).astype(np.int32)
    cur = rng.uniform(0.0, 30.0, size=S).astype(np.float32)

    # 3+4. Relax + min-merge through the L2 round step.
    new, improved = model.relax_batch_minmerge(
        jnp.asarray(prefix.astype(np.int32)), jnp.asarray(src_dist),
        jnp.asarray(eids), jnp.asarray(weights), jnp.asarray(valid),
        jnp.asarray(dst_slot), jnp.asarray(cur))

    _, want = _numpy_round(degs, src_dist, ids, weights[:total],
                           dst_slot[:total], cur)
    np.testing.assert_allclose(np.asarray(new), want, rtol=1e-6)
    got_improved = np.asarray(improved)
    np.testing.assert_array_equal(got_improved, (want < cur).astype(np.int32))


def test_order_invariance_cyclic_equals_blocked():
    """The two distributions must produce identical merged labels — they
    differ in memory behaviour only (paper §4.1)."""
    degs, src_dist, rng = _round_inputs(123)
    total = int(degs.sum())
    weights = rng.uniform(0.0, 4.0, size=total).astype(np.float32)
    dst_slot = rng.integers(0, S, size=total).astype(np.int32)
    cur = rng.uniform(0.0, 30.0, size=S).astype(np.float32)

    outs = []
    for order in ["cyclic", "blocked"]:
        ids = np.arange(total, dtype=np.int32)
        p = 64
        if order == "cyclic":
            perm = np.concatenate([np.arange(t, total, p) for t in range(p)])
        else:
            w = -(-total // p)
            perm = np.concatenate(
                [np.arange(t * w, min((t + 1) * w, total)) for t in range(p)])
        eids = np.zeros(B, np.int32)
        eids[:total] = ids[perm]
        wts = np.zeros(B, np.float32)
        wts[:total] = weights[perm]
        slots = np.zeros(B, np.int32)
        slots[:total] = dst_slot[perm]
        valid = np.zeros(B, np.int32)
        valid[:total] = 1
        prefix = np.cumsum(degs).astype(np.int32)
        new, _ = model.relax_batch_minmerge(
            jnp.asarray(prefix), jnp.asarray(src_dist), jnp.asarray(eids),
            jnp.asarray(wts), jnp.asarray(valid), jnp.asarray(slots),
            jnp.asarray(cur))
        outs.append(np.asarray(new))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
