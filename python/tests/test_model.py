"""L2 correctness: model.py round steps — semantics and shape contracts."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")

H, B, S = 256, 2048, 2048


def _case(seed):
    rng = np.random.default_rng(seed)
    degs = rng.integers(1, 512, size=H).astype(np.int32)
    prefix = np.cumsum(degs).astype(np.int32)
    src_dist = rng.uniform(0.0, 50.0, size=H).astype(np.float32)
    eids = rng.integers(0, int(prefix[-1]), size=B).astype(np.int32)
    weights = rng.uniform(0.0, 5.0, size=B).astype(np.float32)
    valid = (rng.random(B) < 0.95).astype(np.int32)
    return prefix, src_dist, eids, weights, valid, rng


@given(st.integers(min_value=0, max_value=9999))
def test_relax_batch_matches_ref(seed):
    prefix, src_dist, eids, weights, valid, _ = _case(seed)
    src, cand = model.relax_batch(*map(jnp.asarray,
                                       (prefix, src_dist, eids, weights,
                                        valid)))
    ws, wc = ref.edge_relax(jnp.asarray(prefix), jnp.asarray(src_dist),
                            jnp.asarray(eids), jnp.asarray(weights),
                            jnp.asarray(valid) != 0)
    np.testing.assert_array_equal(np.asarray(src), np.asarray(ws))
    np.testing.assert_allclose(np.asarray(cand), np.asarray(wc), rtol=1e-6)


@given(st.integers(min_value=0, max_value=9999))
def test_relax_merge_is_min_reduction(seed):
    """relax_batch_minmerge == per-slot min of relax_batch candidates,
    combined with the current slot distances."""
    prefix, src_dist, eids, weights, valid, rng = _case(seed)
    dst_slot = rng.integers(0, S, size=B).astype(np.int32)
    cur = rng.uniform(0.0, 100.0, size=S).astype(np.float32)

    new, improved = model.relax_batch_minmerge(
        *map(jnp.asarray, (prefix, src_dist, eids, weights, valid,
                           dst_slot, cur)))
    new = np.asarray(new)
    improved = np.asarray(improved)

    _, cand = ref.edge_relax(jnp.asarray(prefix), jnp.asarray(src_dist),
                             jnp.asarray(eids), jnp.asarray(weights),
                             jnp.asarray(valid) != 0)
    cand = np.asarray(cand)
    want = cur.copy()
    for i in range(B):
        if valid[i]:
            s = dst_slot[i]
            want[s] = min(want[s], cand[i])
    np.testing.assert_allclose(new, want, rtol=1e-6)
    np.testing.assert_array_equal(improved, (want < cur).astype(np.int32))


def test_relax_merge_no_valid_edges_is_identity():
    prefix, src_dist, eids, weights, _, rng = _case(0)
    valid = np.zeros(B, np.int32)
    dst_slot = rng.integers(0, S, size=B).astype(np.int32)
    cur = rng.uniform(0.0, 100.0, size=S).astype(np.float32)
    new, improved = model.relax_batch_minmerge(
        *map(jnp.asarray, (prefix, src_dist, eids, weights, valid,
                           dst_slot, cur)))
    np.testing.assert_allclose(np.asarray(new), cur)
    assert np.all(np.asarray(improved) == 0)


def test_inspect_prefix_total_edges():
    degs = np.zeros(H, np.int32)
    degs[:10] = 1000
    (prefix,) = model.inspect_prefix(jnp.asarray(degs))
    assert int(np.asarray(prefix)[-1]) == 10_000  # paper's total_edges


def test_pr_round_conserves_scaling():
    n = 4096
    rng = np.random.default_rng(7)
    ranks = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    degs = np.ones(n, np.int32)
    (contrib,) = model.pr_round(jnp.asarray(ranks), jnp.asarray(degs),
                                jnp.asarray([0.85], jnp.float32))
    np.testing.assert_allclose(np.asarray(contrib), 0.85 * ranks, rtol=1e-6)


def test_kcore_round_mask():
    n = 4096
    degs = np.arange(n, dtype=np.int32) % 256
    (alive,) = model.kcore_round(jnp.asarray(degs),
                                 jnp.asarray([100], jnp.int32))
    np.testing.assert_array_equal(np.asarray(alive),
                                  (degs % 256 >= 100).astype(np.int32))
