"""AOT pipeline checks: manifest consistency + HLO-text lowering sanity.

Full artifact regeneration is exercised by ``make artifacts``; here we verify
the manifest the Rust runtime consumes matches what aot.py would emit, and
that the HLO-text conversion produces parseable modules (entry computation,
parameter count).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entries_unique_names():
    names = [name for name, *_ in aot._entries()]
    assert len(names) == len(set(names))


def test_entries_cover_all_kinds():
    kinds = {meta["kind"] for *_, meta in aot._entries()}
    assert kinds == {"edge_relax", "relax_merge", "prefix_sum", "pr_pull",
                     "kcore", "binning"}


def test_hlo_text_has_entry_and_params():
    spec = jax.ShapeDtypeStruct((256,), jnp.int32)
    lowered = jax.jit(model.inspect_prefix).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text
    # return_tuple=True: root is a tuple — required by the rust loader's
    # to_tuple unwrapping.
    assert "tuple" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_entries_and_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    on_disk = {a["name"]: a for a in manifest["artifacts"]}
    expected = {name: (specs, meta) for name, _, specs, meta in
                ((n, f, s, m) for n, f, s, m in aot._entries())}
    assert set(on_disk) == set(expected)
    for name, (specs, meta) in expected.items():
        entry = on_disk[name]
        assert entry["kind"] == meta["kind"]
        assert [tuple(i["shape"]) for i in entry["inputs"]] == \
            [s.shape for s in specs]
        assert os.path.exists(os.path.join(ART, entry["file"]))
