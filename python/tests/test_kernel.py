"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

Hypothesis sweeps shapes/values; fixed cases pin the paper-relevant regimes
(one mega-degree vertex, all-equal degrees, empty batch padding).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binning as bk
from compile.kernels import edge_relax as ek
from compile.kernels import pr_pull as pk
from compile.kernels import prefix_sum as sk
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _degrees_to_prefix(degs):
    return np.cumsum(np.asarray(degs, np.int32)).astype(np.int32)


# ---------------------------------------------------------------- prefix sum

@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=2**20),
    st.integers(min_value=0, max_value=12345),
)
def test_prefix_sum_matches_ref(tiles, hi, seed):
    n = tiles * sk.DEFAULT_TILE
    rng = np.random.default_rng(seed)
    degs = rng.integers(0, max(hi, 1), size=n).astype(np.int32)
    got = np.asarray(sk.prefix_sum(jnp.asarray(degs)))
    want = np.asarray(ref.prefix_sum(jnp.asarray(degs)))
    np.testing.assert_array_equal(got, want)


def test_prefix_sum_carry_crosses_tiles():
    n = 2 * sk.DEFAULT_TILE
    degs = np.ones(n, np.int32)
    got = np.asarray(sk.prefix_sum(jnp.asarray(degs)))
    np.testing.assert_array_equal(got, np.arange(1, n + 1, dtype=np.int32))


def test_prefix_sum_rejects_ragged():
    with pytest.raises(ValueError):
        sk.prefix_sum(jnp.zeros(sk.DEFAULT_TILE + 1, jnp.int32))


# ---------------------------------------------------------------- edge relax

def _relax_case(h, b, seed, max_deg=2048):
    rng = np.random.default_rng(seed)
    degs = rng.integers(1, max_deg, size=h).astype(np.int32)
    prefix = _degrees_to_prefix(degs)
    total = int(prefix[-1])
    src_dist = rng.uniform(0.0, 100.0, size=h).astype(np.float32)
    eids = rng.integers(0, total, size=b).astype(np.int32)
    weights = rng.uniform(0.0, 10.0, size=b).astype(np.float32)
    valid = (rng.random(b) < 0.9).astype(np.int32)
    return prefix, src_dist, eids, weights, valid


@given(st.integers(min_value=0, max_value=99999))
def test_edge_relax_matches_ref(seed):
    h, b = 256, ek.DEFAULT_TILE
    prefix, src_dist, eids, weights, valid = _relax_case(h, b, seed)
    args = tuple(map(jnp.asarray, (prefix, src_dist, eids, weights, valid)))
    gs, gc = ek.edge_relax(*args)
    ws, wc = ref.edge_relax(*args[:4], args[4] != 0)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_allclose(np.asarray(gc), np.asarray(wc), rtol=1e-6)


def test_edge_relax_single_mega_vertex():
    """Paper Fig 5a regime: one vertex owns every edge -> src is always 0."""
    h, b = 256, ek.DEFAULT_TILE
    degs = np.zeros(h, np.int32)
    degs[0] = 10_000
    prefix = _degrees_to_prefix(degs)
    src_dist = np.full(h, 7.0, np.float32)
    eids = np.arange(b, dtype=np.int32)
    weights = np.ones(b, np.float32)
    valid = np.ones(b, np.int32)
    src, cand = ek.edge_relax(*map(jnp.asarray,
                                   (prefix, src_dist, eids, weights, valid)))
    assert np.all(np.asarray(src) == 0)
    np.testing.assert_allclose(np.asarray(cand), 8.0)


def test_edge_relax_boundaries_exact():
    """Edge ids exactly at prefix boundaries belong to the *next* vertex."""
    h, b = 256, ek.DEFAULT_TILE
    degs = np.full(h, 4, np.int32)
    prefix = _degrees_to_prefix(degs)
    src_dist = np.arange(h, dtype=np.float32)
    eids = np.zeros(b, np.int32)
    eids[:6] = [0, 3, 4, 7, 8, 1023]
    weights = np.zeros(b, np.float32)
    valid = np.ones(b, np.int32)
    src, cand = ek.edge_relax(*map(jnp.asarray,
                                   (prefix, src_dist, eids, weights, valid)))
    got = np.asarray(src)[:6]
    np.testing.assert_array_equal(got, [0, 0, 1, 1, 2, 255])
    np.testing.assert_allclose(np.asarray(cand)[:6], got.astype(np.float32))


def test_edge_relax_invalid_lanes_are_inf():
    h, b = 256, ek.DEFAULT_TILE
    prefix, src_dist, eids, weights, _ = _relax_case(h, b, seed=1)
    valid = np.zeros(b, np.int32)
    src, cand = ek.edge_relax(*map(jnp.asarray,
                                   (prefix, src_dist, eids, weights, valid)))
    assert np.all(np.asarray(src) == 0)
    assert np.all(np.asarray(cand) == float(ref.INF))


def test_edge_relax_multi_tile_grid():
    h, b = 256, 4 * ek.DEFAULT_TILE
    prefix, src_dist, eids, weights, valid = _relax_case(h, b, seed=3)
    args = tuple(map(jnp.asarray, (prefix, src_dist, eids, weights, valid)))
    gs, gc = ek.edge_relax(*args)
    ws, wc = ref.edge_relax(*args[:4], args[4] != 0)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_allclose(np.asarray(gc), np.asarray(wc), rtol=1e-6)


def test_edge_relax_rejects_ragged_batch():
    with pytest.raises(ValueError):
        ek.edge_relax(
            jnp.zeros(8, jnp.int32), jnp.zeros(8, jnp.float32),
            jnp.zeros(100, jnp.int32), jnp.zeros(100, jnp.float32),
            jnp.zeros(100, jnp.int32))


# ------------------------------------------------------------------ pr_pull

@given(st.integers(min_value=0, max_value=99999),
       st.floats(min_value=0.5, max_value=0.99))
def test_pr_pull_matches_ref(seed, damping):
    n = pk.DEFAULT_TILE
    rng = np.random.default_rng(seed)
    ranks = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    degs = rng.integers(0, 1000, size=n).astype(np.int32)
    got = pk.pr_pull_contrib(jnp.asarray(ranks), jnp.asarray(degs),
                             jnp.asarray([damping], jnp.float32))
    want = ref.pr_pull_contrib(jnp.asarray(ranks), jnp.asarray(degs),
                               damping)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_pr_pull_zero_degree_guard():
    n = pk.DEFAULT_TILE
    ranks = np.full(n, 0.5, np.float32)
    degs = np.zeros(n, np.int32)
    got = pk.pr_pull_contrib(jnp.asarray(ranks), jnp.asarray(degs),
                             jnp.asarray([0.85], jnp.float32))
    np.testing.assert_allclose(np.asarray(got), 0.425)  # /max(deg,1)


# -------------------------------------------------------------------- kcore

@given(st.integers(min_value=0, max_value=99999),
       st.integers(min_value=0, max_value=200))
def test_kcore_matches_ref(seed, k):
    n = pk.DEFAULT_TILE
    rng = np.random.default_rng(seed)
    degs = rng.integers(0, 300, size=n).astype(np.int32)
    got = pk.kcore_alive(jnp.asarray(degs), jnp.asarray([k], jnp.int32))
    want = ref.kcore_alive(jnp.asarray(degs), k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kcore_threshold_inclusive():
    n = pk.DEFAULT_TILE
    degs = np.full(n, 100, np.int32)
    got = pk.kcore_alive(jnp.asarray(degs), jnp.asarray([100], jnp.int32))
    assert np.all(np.asarray(got) == 1)


# ------------------------------------------------------------------ binning

@given(st.integers(min_value=0, max_value=99999))
def test_binning_matches_ref(seed):
    n = bk.DEFAULT_TILE
    rng = np.random.default_rng(seed)
    degs = rng.integers(0, 10_000, size=n).astype(np.int32)
    cuts = jnp.asarray([32, 128, 3072], jnp.int32)
    got = bk.twc_bin(jnp.asarray(degs), cuts)
    want = ref.twc_bin(jnp.asarray(degs), 32, 128, 3072)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_binning_boundaries():
    n = bk.DEFAULT_TILE
    degs = np.zeros(n, np.int32)
    degs[:8] = [0, 31, 32, 127, 128, 3071, 3072, 1 << 30]
    cuts = jnp.asarray([32, 128, 3072], jnp.int32)
    got = np.asarray(bk.twc_bin(jnp.asarray(degs), cuts))
    np.testing.assert_array_equal(got[:8], [0, 0, 1, 1, 2, 2, 3, 3])
