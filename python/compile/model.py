"""Layer-2 JAX round-step functions, composed from the Layer-1 Pallas kernels.

Each function here is a *whole round step* as the Rust coordinator consumes it:
one jitted computation, lowered once by ``aot.py`` to an HLO-text artifact and
executed from ``rust/src/runtime/`` via PJRT. Python never runs at request
time.

The split of the paper's round across layers:

  Rust L3 (coordinator)    decides WHICH edges — inspector bins vertices,
                           builds the cyclic/blocked edge-id schedule,
                           owns worklists + CSR + labels.
  JAX  L2 (this module)    the numeric round step over a fixed-shape batch:
                           prefix-sum inspection, LB-kernel relaxation with
                           per-destination-slot min-merge, pr/kcore steps.
  Pallas L1 (kernels/)     the hot inner loops (vectorized search + relax,
                           tiled scan, element ops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import binning as _binning_k
from .kernels import edge_relax as _edge_relax_k
from .kernels import pr_pull as _pr_pull_k
from .kernels import prefix_sum as _prefix_sum_k
from .kernels import ref


def inspect_prefix(degrees):
    """Inspector phase: huge-vertex degrees i32[H] -> inclusive prefix i32[H].

    ``prefix[-1]`` is ``total_edges`` (paper Fig. 3 line 14).
    """
    return (_prefix_sum_k.prefix_sum(degrees),)


def relax_batch(prefix, src_dist, edge_ids, weights, valid):
    """Executor phase: relax one fixed-shape batch of distributed edges.

    Returns (src_idx i32[B], candidate f32[B]). The host applies the
    atomicMin merge against its labels (it knows eid -> dst from CSR).
    """
    src, cand = _edge_relax_k.edge_relax(prefix, src_dist, edge_ids, weights,
                                         valid)
    return src, cand


def relax_batch_minmerge(prefix, src_dist, edge_ids, weights, valid,
                         dst_slot, cur_slot_dist):
    """Relax + deterministic min-merge into destination *slots*.

    ``dst_slot`` i32[B] maps each edge lane to a dense slot in [0, S); the
    kernel's candidates are segment-min-reduced per slot and combined with the
    slot's current distance. This is the deterministic TPU replacement for
    CUDA ``atomicMin`` (DESIGN.md §6): the host picks S and the slot mapping
    (typically dst vertices touched this batch), and gets back the merged
    labels plus an "improved" mask for worklist pushes.

    Returns (new_slot_dist f32[S], improved i32[S]).
    """
    (s,) = cur_slot_dist.shape
    _, cand = _edge_relax_k.edge_relax(prefix, src_dist, edge_ids, weights,
                                       valid)
    seg_min = jnp.full((s,), ref.INF, jnp.float32).at[dst_slot].min(
        jnp.where(valid != 0, cand, ref.INF))
    new = jnp.minimum(cur_slot_dist, seg_min)
    improved = (new < cur_slot_dist).astype(jnp.int32)
    return new, improved


def pr_round(ranks, out_degree, damping):
    """Pull-style pagerank contributions for a tile of vertices."""
    return (_pr_pull_k.pr_pull_contrib(ranks, out_degree, damping),)


def kcore_round(cur_degree, k):
    """One k-core filter step over a tile of vertices."""
    return (_pr_pull_k.kcore_alive(cur_degree, k),)


def inspect_bins(degrees, cuts):
    """Inspector bin assignment for a tile of active vertices."""
    return (_binning_k.twc_bin(degrees, cuts),)
