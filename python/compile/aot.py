"""AOT pipeline: lower every Layer-2 round step to HLO *text* artifacts.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.
(See /opt/xla-example/gen_hlo.py and its README.)

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Writes one ``<name>.hlo.txt`` per (function, shape-variant) plus
``manifest.json`` describing each artifact's I/O signature, which
``rust/src/runtime/artifact.rs`` consumes to pick batch variants.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape variants compiled ahead of time. The Rust runtime pads a request to
# the smallest variant that fits (H = huge-vertex table size, B = edge batch,
# S = destination-slot table, N = vertex tile).
RELAX_VARIANTS = [(256, 2048), (1024, 8192)]        # (H, B)
RELAX_MERGE_VARIANTS = [(256, 2048, 2048)]          # (H, B, S)
PREFIX_VARIANTS = [256, 1024]                       # H (tile multiple of 256)
VERTEX_VARIANTS = [4096, 16384]                     # N (tile mult of 1024)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _entries():
    """Yield (name, fn, arg_specs, meta) for every artifact."""
    for h, b in RELAX_VARIANTS:
        yield (
            f"edge_relax_h{h}_b{b}",
            model.relax_batch,
            [
                _spec((h,), jnp.int32),    # prefix
                _spec((h,), jnp.float32),  # src_dist
                _spec((b,), jnp.int32),    # edge_ids
                _spec((b,), jnp.float32),  # weights
                _spec((b,), jnp.int32),    # valid
            ],
            {"kind": "edge_relax", "h": h, "b": b,
             "outputs": ["src_idx:i32", "candidate:f32"]},
        )
    for h, b, s in RELAX_MERGE_VARIANTS:
        yield (
            f"relax_merge_h{h}_b{b}_s{s}",
            model.relax_batch_minmerge,
            [
                _spec((h,), jnp.int32),
                _spec((h,), jnp.float32),
                _spec((b,), jnp.int32),
                _spec((b,), jnp.float32),
                _spec((b,), jnp.int32),
                _spec((b,), jnp.int32),    # dst_slot
                _spec((s,), jnp.float32),  # cur_slot_dist
            ],
            {"kind": "relax_merge", "h": h, "b": b, "s": s,
             "outputs": ["new_slot_dist:f32", "improved:i32"]},
        )
    for h in PREFIX_VARIANTS:
        yield (
            f"prefix_sum_h{h}",
            model.inspect_prefix,
            [_spec((h,), jnp.int32)],
            {"kind": "prefix_sum", "h": h, "outputs": ["prefix:i32"]},
        )
    for n in VERTEX_VARIANTS:
        yield (
            f"binning_n{n}",
            model.inspect_bins,
            [
                _spec((n,), jnp.int32),    # degrees
                _spec((3,), jnp.int32),    # (warp, block, huge) cutoffs
            ],
            {"kind": "binning", "n": n, "outputs": ["bins:i32"]},
        )
        yield (
            f"pr_pull_n{n}",
            model.pr_round,
            [
                _spec((n,), jnp.float32),  # ranks
                _spec((n,), jnp.int32),    # out_degree
                _spec((1,), jnp.float32),  # damping
            ],
            {"kind": "pr_pull", "n": n, "outputs": ["contrib:f32"]},
        )
        yield (
            f"kcore_n{n}",
            model.kcore_round,
            [
                _spec((n,), jnp.int32),    # cur_degree
                _spec((1,), jnp.int32),    # k
            ],
            {"kind": "kcore", "n": n, "outputs": ["alive:i32"]},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, specs, meta in _entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            **meta,
        }
        manifest["artifacts"].append(entry)
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
