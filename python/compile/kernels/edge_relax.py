"""Layer-1 Pallas kernel: the paper's LB ("load-balance") kernel body.

The CUDA original (paper Figure 3, ``SSSP_LB``) gives each GPU thread a cyclic
slice of the huge-vertex edge set; every thread binary-searches the prefix-sum
worklist in global memory to recover its edge's source vertex, then applies the
relaxation operator.

TPU re-think (DESIGN.md §6 Hardware-Adaptation):

* the edge batch is tiled ``(TILE,)`` per grid step via BlockSpec — the grid
  plays the role of the threadblock sweep, and the *cyclic vs blocked* choice
  lives entirely in how the host (Rust L3) fills ``edge_ids``, so one compiled
  kernel serves both schedules;
* the prefix-sum array and the huge-vertex labels are small (``H`` entries) and
  are mapped whole into VMEM each step — the warp-coherent binary search
  becomes one vectorized rank computation (``prefix <= eid`` compare plane,
  reduced over the H axis), which is the natural 8x128-lane formulation;
* ``atomicMin`` is deferred: the kernel returns per-edge candidates and the
  host (or the L2 segment-min wrapper) merges them, keeping the kernel
  deterministic.

Checked against ``ref.edge_relax`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Lane-dimension tile for the edge batch. 8 * 128 keeps the compare plane
#: (TILE x H) within a ~4 MiB VMEM budget for H <= 1024 (see DESIGN.md §7).
DEFAULT_TILE = 1024


def _relax_kernel(prefix_ref, src_dist_ref, eid_ref, weight_ref, valid_ref,
                  src_out_ref, cand_out_ref):
    """One grid step: relax TILE edges against the whole huge-vertex table."""
    prefix = prefix_ref[...]
    eid = eid_ref[...].astype(jnp.int32)
    valid = valid_ref[...] != 0

    # Vectorized "binary search": rank of eid in the inclusive prefix array.
    # (TILE, H) compare plane lives in VMEM; reduction over H is lane-parallel.
    src = jnp.sum(prefix[None, :] <= eid[:, None], axis=1).astype(jnp.int32)
    src = jnp.where(valid, src, 0)

    cand = jnp.take(src_dist_ref[...], src, axis=0) + weight_ref[...]
    cand = jnp.where(valid, cand, ref.INF).astype(jnp.float32)

    src_out_ref[...] = src
    cand_out_ref[...] = cand


@functools.partial(jax.jit, static_argnames=("tile",))
def edge_relax(prefix, src_dist, edge_ids, weights, valid, *,
               tile: int = DEFAULT_TILE):
    """Relax a batch of distributed edges (paper's LB kernel).

    Args:
      prefix:   i32[H] inclusive prefix sum of huge-vertex out-degrees.
      src_dist: f32[H] current labels of the huge vertices.
      edge_ids: i32[B] edge ids in [0, prefix[-1]) — cyclic or blocked order.
      weights:  f32[B] edge weights (1.0 for bfs hops, 0.0 for cc).
      valid:    i32[B] nonzero where the lane carries a real edge.
      tile:     lane tile; B must be a multiple of it.

    Returns:
      (src_idx i32[B], candidate f32[B]); padded lanes give (0, INF).
    """
    (b,) = edge_ids.shape
    if b % tile != 0:
        raise ValueError(f"batch {b} not a multiple of tile {tile}")
    grid = (b // tile,)
    whole = lambda i: (0,)  # full-array block, re-fetched each step
    lane = lambda i: (i,)

    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(prefix.shape, whole),
            pl.BlockSpec(src_dist.shape, whole),
            pl.BlockSpec((tile,), lane),
            pl.BlockSpec((tile,), lane),
            pl.BlockSpec((tile,), lane),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lane),
            pl.BlockSpec((tile,), lane),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only
    )(prefix, src_dist, edge_ids, weights, valid)
