"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
checked elementwise against the function of the same name here (pytest +
hypothesis sweeps in ``python/tests/``).

Semantics mirror the paper's LB ("load-balance") kernel, Figure 3/4 of
*An Adaptive Load Balancer For Graph Analytical Applications on GPUs*:

* ``prefix_sum``      — the inspector's inclusive scan over huge-vertex degrees
                        (paper line 31, ``computePrefixSum``).
* ``edge_to_src``     — the executor's binary search: map a global edge id to
                        the index of the huge vertex owning it (paper Figure 4).
* ``edge_relax``      — the relaxation operator applied per distributed edge:
                        candidate = dist(src) + weight  (min-plus semiring;
                        weight 1 == bfs hop, weight 0 == cc label propagate).
* ``pr_pull_contrib`` — pull-style pagerank per-vertex contribution
                        (rank / out_degree, damped).
* ``kcore_alive``     — one k-core filter step: vertex stays if its current
                        degree >= k.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Sentinel "infinite" distance. f32-exact, survives +weight without overflow.
#: Kept a plain Python float so Pallas kernels can close over it.
INF = float(2.0**30)


def prefix_sum(degrees: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum of ``degrees`` (i32[N] -> i32[N]).

    ``out[j] == degrees[:j+1].sum()``; ``out[-1]`` is the LB kernel's
    ``total_edges``.
    """
    return jnp.cumsum(degrees.astype(jnp.int32), dtype=jnp.int32)


def edge_to_src(prefix: jnp.ndarray, edge_ids: jnp.ndarray) -> jnp.ndarray:
    """Map global edge ids to owning-vertex indices via the prefix array.

    Vertex ``j`` owns edge ids ``[prefix[j-1], prefix[j])`` (with
    ``prefix[-1] == 0``).  Equivalent to the paper's binary search on the
    prefix-sum worklist; expressed as a rank computation (count of prefix
    entries <= id), which is what the vectorized VMEM search computes.
    """
    eid = edge_ids.astype(jnp.int32)
    # searchsorted-right: number of prefix ends that are <= eid.
    return jnp.sum(prefix[None, :] <= eid[:, None], axis=1).astype(jnp.int32)


def edge_relax(
    prefix: jnp.ndarray,
    src_dist: jnp.ndarray,
    edge_ids: jnp.ndarray,
    weights: jnp.ndarray,
    valid: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The LB-kernel body: edge id -> (src index, candidate distance).

    Args:
      prefix:   i32[H]  inclusive prefix sum of huge-vertex out-degrees.
      src_dist: f32[H]  current label (distance) of each huge vertex.
      edge_ids: i32[B]  global edge ids assigned to this batch (any schedule —
                cyclic / blocked is the caller's concern).
      weights:  f32[B]  weight of each edge.
      valid:    bool[B] mask; padded lanes yield (0, INF).

    Returns:
      (src_idx i32[B], candidate f32[B]) with candidate = src_dist[src] + w.
    """
    src = edge_to_src(prefix, edge_ids)
    src = jnp.where(valid, src, 0).astype(jnp.int32)
    cand = jnp.take(src_dist, src, axis=0) + weights
    cand = jnp.where(valid, cand, INF).astype(jnp.float32)
    return src, cand


def pr_pull_contrib(
    ranks: jnp.ndarray, out_degree: jnp.ndarray, damping: float = 0.85
) -> jnp.ndarray:
    """Per-vertex pull contribution: damping * rank / max(out_degree, 1)."""
    deg = jnp.maximum(out_degree.astype(jnp.float32), 1.0)
    return (damping * ranks / deg).astype(jnp.float32)


def pr_update(
    acc: jnp.ndarray, n: int, damping: float = 0.85
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """New rank from accumulated neighbor contributions + the residual used
    for the convergence check."""
    base = jnp.float32((1.0 - damping) / n)
    new_rank = base + acc
    return new_rank.astype(jnp.float32), jnp.abs(new_rank).astype(jnp.float32)


def kcore_alive(cur_degree: jnp.ndarray, k: int) -> jnp.ndarray:
    """k-core filter step: 1 if the vertex survives this round else 0."""
    return (cur_degree.astype(jnp.int32) >= jnp.int32(k)).astype(jnp.int32)


def twc_bin(degrees, warp_size: int, block_threads: int, huge: int):
    """TWC + huge binning (paper Fig. 3 lines 3-9): 0 = thread bin
    (< warp), 1 = warp bin (< block), 2 = CTA bin, 3 = huge (>= THRESHOLD).
    """
    d = degrees.astype(jnp.int32)
    return jnp.where(
        d >= jnp.int32(huge), 3,
        jnp.where(d >= jnp.int32(block_threads), 2,
                  jnp.where(d >= jnp.int32(warp_size), 1, 0))).astype(jnp.int32)
