"""Layer-1 Pallas kernel: the ALB inspector's bin assignment.

Classifies each active vertex by degree into thread / warp / CTA / huge
(paper Fig. 3 lines 3-9) in one vectorized pass — the fused inspection the
generated TWC kernel performs before pushing huge vertices to the LB
worklist. Checked against ``ref.twc_bin``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 1024


def _bin_kernel(deg_ref, cuts_ref, o_ref):
    d = deg_ref[...].astype(jnp.int32)
    warp, block, huge = cuts_ref[0], cuts_ref[1], cuts_ref[2]
    o_ref[...] = jnp.where(
        d >= huge, 3,
        jnp.where(d >= block, 2, jnp.where(d >= warp, 1, 0))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile",))
def twc_bin(degrees, cuts, *, tile: int = DEFAULT_TILE):
    """i32[N] degrees, i32[3] (warp, block, huge) cutoffs -> i32[N] bins."""
    (n,) = degrees.shape
    if n % tile != 0:
        raise ValueError(f"length {n} not a multiple of tile {tile}")
    lane = lambda i: (i,)
    whole = lambda i: (0,)
    return pl.pallas_call(
        _bin_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lane), pl.BlockSpec((3,), whole)],
        out_specs=pl.BlockSpec((tile,), lane),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(degrees.astype(jnp.int32), cuts.astype(jnp.int32))
