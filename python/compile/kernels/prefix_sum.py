"""Layer-1 Pallas kernel: the inspector's prefix sum (paper Fig. 3 line 31).

``computePrefixSum(work, prefixWork)`` in the paper turns the huge-vertex
degree worklist into the inclusive prefix array the LB kernel binary-searches.

TPU formulation: a tiled scan — the grid walks lane tiles in order, a scalar
carry rides in SMEM scratch between steps (grid steps execute sequentially on
a TPU core, and in interpret mode, so the carry is well-defined).

Checked against ``ref.prefix_sum``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE = 256


def _scan_kernel(x_ref, o_ref, carry_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[0] = jnp.int32(0)

    carry = carry_ref[0]
    local = jnp.cumsum(x_ref[...].astype(jnp.int32), dtype=jnp.int32)
    o_ref[...] = local + carry
    carry_ref[0] = carry + local[-1]


@functools.partial(jax.jit, static_argnames=("tile",))
def prefix_sum(degrees, *, tile: int = DEFAULT_TILE):
    """Inclusive prefix sum of i32[N] degrees; N must be a tile multiple."""
    (n,) = degrees.shape
    if n % tile != 0:
        raise ValueError(f"length {n} not a multiple of tile {tile}")
    lane = lambda i: (i,)
    return pl.pallas_call(
        _scan_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lane)],
        out_specs=pl.BlockSpec((tile,), lane),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=True,
    )(degrees.astype(jnp.int32))
