"""Layer-1 Pallas kernels for the pull-style applications (pr, kcore).

``pr_pull_contrib`` computes each vertex's damped contribution
(rank / out_degree) — the value a pull-style pagerank round gathers from
in-neighbors. ``kcore_alive`` is one filter step of k-core decomposition.

Both are elementwise lane-tiled kernels: the interesting scheduling work for
pull apps happens in the coordinator (no huge-bin trigger, per the paper —
in-degree skew is low on RMAT), so the kernels are straight VPU element ops.

Checked against ``ref.pr_pull_contrib`` / ``ref.kcore_alive``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 1024


def _pr_kernel(rank_ref, deg_ref, damp_ref, o_ref):
    deg = jnp.maximum(deg_ref[...].astype(jnp.float32), 1.0)
    o_ref[...] = (damp_ref[0] * rank_ref[...] / deg).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile",))
def pr_pull_contrib(ranks, out_degree, damping, *, tile: int = DEFAULT_TILE):
    """f32[N] ranks, i32[N] out-degrees, f32[1] damping -> f32[N] contribs."""
    (n,) = ranks.shape
    if n % tile != 0:
        raise ValueError(f"length {n} not a multiple of tile {tile}")
    lane = lambda i: (i,)
    whole = lambda i: (0,)
    return pl.pallas_call(
        _pr_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lane),
            pl.BlockSpec((tile,), lane),
            pl.BlockSpec((1,), whole),
        ],
        out_specs=pl.BlockSpec((tile,), lane),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(ranks, out_degree, damping)


def _kcore_kernel(deg_ref, k_ref, o_ref):
    o_ref[...] = (deg_ref[...] >= k_ref[0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile",))
def kcore_alive(cur_degree, k, *, tile: int = DEFAULT_TILE):
    """i32[N] current degrees, i32[1] k -> i32[N] survival mask."""
    (n,) = cur_degree.shape
    if n % tile != 0:
        raise ValueError(f"length {n} not a multiple of tile {tile}")
    lane = lambda i: (i,)
    whole = lambda i: (0,)
    return pl.pallas_call(
        _kcore_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lane), pl.BlockSpec((1,), whole)],
        out_specs=pl.BlockSpec((tile,), lane),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(cur_degree.astype(jnp.int32), k)
